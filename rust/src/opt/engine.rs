//! The guided-search engine: budgeted, seeded multi-objective optimization
//! over (hardware config, per-layer precision) genomes.
//!
//! Three strategies sit behind one [`Strategy`] trait:
//!
//! * [`Nsga2`] — NSGA-II-style evolutionary search: binary tournament on
//!   (constraint-domination rank, crowding distance), uniform crossover and
//!   step/resample mutation over the [`SearchSpace`] genes, elitist
//!   environmental selection from the parent+child union.
//! * [`RandomSearch`] — uniform sampling, the honesty baseline.
//! * [`HillClimb`] — restarted local search over ±1 axis neighbors with a
//!   random-weight scalarization per restart.
//!
//! Every evaluation is batched through the same predict → dataflow pipeline
//! the streaming sweep uses ([`predict_configs_soa`] + [`eval_point_prepared`]
//! over the thread pool, with the legacy per-point oracle behind
//! `QAPPA_LEGACY_EVAL` / [`OptOptions::legacy_eval`]), deduplicated by
//! genome key, and folded into one global [`IncrementalFrontier`] archive
//! of feasible points.  Budget counts
//! **distinct** evaluations; cache hits are free.  Everything is driven by
//! one [`crate::util::prng::Rng`] stream, so a (strategy, budget, seed)
//! triple reproduces its frontier bit-for-bit.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::accuracy::AccuracyModel;
use crate::api::error::QappaError;
use crate::config::{AcceleratorConfig, QuantSpec};
use crate::coordinator::explorer::DsePoint;
use crate::coordinator::pareto::{IncrementalFrontier, IncrementalFrontierNd};
use crate::coordinator::sweep::{
    eval_point, eval_point_prepared, legacy_eval_env, predict_configs_legacy,
    predict_configs_soa,
};
use crate::dataflow::{EvalContext, Layer, MemoStats, PreparedWorkload};
use crate::model::{Backend, PpaModel};
use crate::obs;
use crate::obs::trace::phase_with;
use crate::opt::genome::{Genome, SearchSpace};
use crate::opt::objective::{Constraints, Objective};
use crate::synth::oracle::{EnergyParams, Ppa};
use crate::util::pool::{parallel_map, workers_for};
use crate::util::prng::Rng;

/// A cooperative cancellation handle for a guided-search run.  Cloning
/// shares the flag; any holder may [`CancelToken::cancel`], and the engine
/// observes it between evaluation batches (via [`Evaluator::remaining`],
/// the loop condition every strategy polls), so a cancelled run stops at
/// the next batch boundary without poisoning shared state.  The network
/// server fires this when a client drops mid-optimize.
#[derive(Debug, Clone, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// Which search strategy drives a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    Nsga2,
    Random,
    HillClimb,
}

impl StrategyKind {
    pub fn label(self) -> &'static str {
        match self {
            StrategyKind::Nsga2 => "nsga2",
            StrategyKind::Random => "random",
            StrategyKind::HillClimb => "hillclimb",
        }
    }

    pub fn parse(s: &str) -> Result<StrategyKind, QappaError> {
        match s.to_ascii_lowercase().as_str() {
            "nsga2" | "nsga-ii" | "nsga" => Ok(StrategyKind::Nsga2),
            "random" => Ok(StrategyKind::Random),
            "hillclimb" | "hill-climb" | "hc" => Ok(StrategyKind::HillClimb),
            other => Err(QappaError::Config(format!(
                "unknown strategy '{other}' (expected nsga2|random|hillclimb)"
            ))),
        }
    }
}

/// One guided-search problem: the domain plus what "better" means.
pub struct OptProblem<'a> {
    pub search: SearchSpace<'a>,
    /// Two or three minimized objectives (see [`crate::opt::objective`]).
    pub objectives: Vec<Objective>,
    pub constraints: Constraints,
    /// Accuracy estimator backing the `accuracy` objective and the
    /// `min_accuracy` constraint; `None` falls back to the structural
    /// proxy when either is in play.
    pub accuracy: Option<AccuracyModel>,
}

impl<'a> OptProblem<'a> {
    /// Whether any objective or constraint needs a per-genome accuracy
    /// estimate.
    pub fn needs_accuracy(&self) -> bool {
        self.objectives.iter().any(|o| o.needs_accuracy())
            || self.constraints.min_accuracy.is_some()
    }
}

/// Engine knobs.
#[derive(Debug, Clone, Copy)]
pub struct OptOptions {
    pub strategy: StrategyKind,
    /// Distinct-evaluation budget (the hard spend cap).
    pub budget: usize,
    /// Population size (NSGA-II) / batch size (random).
    pub pop: usize,
    pub seed: u64,
    /// Force the legacy per-point evaluation path (the pre-SoA oracle the
    /// equivalence suite compares against).  `QAPPA_LEGACY_EVAL=1` has the
    /// same effect; results are bit-identical either way.
    pub legacy_eval: bool,
}

impl Default for OptOptions {
    fn default() -> OptOptions {
        OptOptions {
            strategy: StrategyKind::Nsga2,
            budget: 20_000,
            pop: 64,
            seed: 42,
            legacy_eval: false,
        }
    }
}

/// One evaluated genome: the pipeline's design point plus the problem's
/// view of it.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub point: DsePoint,
    /// Minimized objective values, problem order (one per objective).
    pub objs: Vec<f64>,
    /// Total normalized constraint violation (0 = feasible).
    pub violation: f64,
    /// Top-1 accuracy estimate in [0, 1]; `Some` only when the problem
    /// needs accuracy (objective or `min_accuracy` constraint).
    pub accuracy: Option<f64>,
}

/// Per-generation (or per-round) convergence snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct GenStat {
    pub generation: usize,
    /// Distinct evaluations spent so far.
    pub evaluated: usize,
    /// Archive (global frontier) size.
    pub frontier: usize,
    /// Archive hypervolume w.r.t. the run's fixed reference corner.
    pub hypervolume: f64,
    /// Best (minimum) value seen per objective among feasible points.
    pub best: Vec<f64>,
}

/// One frontier member of a finished run.
#[derive(Debug, Clone)]
pub struct FrontierPoint {
    pub genome: Genome,
    pub point: DsePoint,
    /// Minimized objective values, problem order (one per objective).
    pub objs: Vec<f64>,
    /// Precision labels (one per layer, or a single uniform label).
    pub precision: Vec<String>,
    /// Accuracy estimate; `Some` only on accuracy-aware runs.
    pub accuracy: Option<f64>,
}

/// Result of one guided-search run.
pub struct OptResult {
    pub strategy: &'static str,
    /// Distinct evaluations spent.
    pub evaluated: usize,
    /// The run's reference corner in minimized-objective space (fixed
    /// after the first batch; hypervolumes are measured against it).
    pub ref_point: Vec<f64>,
    /// Final archive hypervolume.
    pub hypervolume: f64,
    /// Global feasible frontier, sorted by the first objective ascending.
    pub frontier: Vec<FrontierPoint>,
    pub generations: Vec<GenStat>,
    /// Evaluation-memo counters for the run (all zero on the legacy path).
    pub memo: MemoStats,
}

// ---------------------------------------------------------------------------
// evaluator
// ---------------------------------------------------------------------------

enum Slot {
    Cached(Vec<u32>),
    Fresh(usize),
    /// Over budget — not evaluated.
    Skipped,
}

/// Frontier payload: the genome, its design point and (on accuracy-aware
/// runs) the accuracy estimate.
type ArchivePayload = (Genome, DsePoint, Option<f64>);

/// The global feasible-frontier archive.  Two-objective runs keep the
/// original transformed-coordinate [`IncrementalFrontier`] (push
/// `(-objs[0], objs[1])`, hypervolume at `(-r[0], r[1])`) bit-for-bit;
/// three-objective runs use the N-dimensional minimized-space archive.
enum Archive {
    Two(IncrementalFrontier<ArchivePayload>),
    Many(IncrementalFrontierNd<ArchivePayload>),
}

impl Archive {
    fn new(nobj: usize) -> Archive {
        if nobj == 2 {
            Archive::Two(IncrementalFrontier::new())
        } else {
            Archive::Many(IncrementalFrontierNd::new(nobj))
        }
    }

    fn push(&mut self, objs: &[f64], payload: ArchivePayload) -> bool {
        match self {
            Archive::Two(f) => f.push(-objs[0], objs[1], payload),
            Archive::Many(f) => f.push(objs, payload),
        }
    }

    fn len(&self) -> usize {
        match self {
            Archive::Two(f) => f.len(),
            Archive::Many(f) => f.len(),
        }
    }

    fn hypervolume(&self, r: &[f64]) -> f64 {
        match self {
            Archive::Two(f) => f.hypervolume((-r[0], r[1])),
            Archive::Many(f) => f.hypervolume(r),
        }
    }

    fn into_payloads(self) -> Vec<ArchivePayload> {
        match self {
            Archive::Two(f) => f.into_entries().into_iter().map(|e| e.payload).collect(),
            Archive::Many(f) => f.into_entries().into_iter().map(|e| e.payload).collect(),
        }
    }
}

/// Batched, cached, budget-capped evaluation of genomes, folding every
/// feasible point into the global frontier archive.
pub struct Evaluator<'a> {
    backend: &'a dyn Backend,
    model: &'a PpaModel,
    problem: &'a OptProblem<'a>,
    workers: usize,
    budget: usize,
    cache: HashMap<Vec<u32>, EvalRecord>,
    /// Distinct evaluations spent.
    pub evaluated: usize,
    /// Global feasible frontier (see [`Archive`]).
    archive: Archive,
    /// Objective count (2 or 3), cached off the problem.
    nobj: usize,
    /// Accuracy estimator, materialized only when the problem needs it.
    acc_model: Option<AccuracyModel>,
    /// Fixed after the first batch (see [`Evaluator::freeze_ref`]).
    ref_point: Option<Vec<f64>>,
    max_feasible: Option<Vec<f64>>,
    max_all: Vec<f64>,
    best: Vec<f64>,
    /// Per-point legacy evaluation (the pre-SoA oracle).
    legacy: bool,
    /// Cooperative cancellation: when fired, `remaining()` reports 0 and
    /// every strategy's budget loop exits at its next batch boundary.
    cancel: CancelToken,
    /// Run-wide memo state: synthesis derivations and layer costs cached
    /// across batches and generations.
    ctx: EvalContext,
}

impl<'a> Evaluator<'a> {
    pub fn new(
        backend: &'a dyn Backend,
        model: &'a PpaModel,
        problem: &'a OptProblem<'a>,
        workers: usize,
        budget: usize,
    ) -> Evaluator<'a> {
        let nobj = problem.objectives.len();
        let acc_model = if problem.needs_accuracy() {
            Some(problem.accuracy.clone().unwrap_or_else(AccuracyModel::proxy))
        } else {
            None
        };
        Evaluator {
            backend,
            model,
            problem,
            workers,
            budget,
            cache: HashMap::new(),
            evaluated: 0,
            archive: Archive::new(nobj),
            nobj,
            acc_model,
            ref_point: None,
            max_feasible: None,
            max_all: vec![f64::NEG_INFINITY; nobj],
            best: vec![f64::INFINITY; nobj],
            legacy: legacy_eval_env(),
            cancel: CancelToken::new(),
            ctx: EvalContext::new(),
        }
    }

    /// Force the legacy per-point evaluation path (the test oracle),
    /// independent of `QAPPA_LEGACY_EVAL`.
    pub fn legacy(mut self, yes: bool) -> Evaluator<'a> {
        self.legacy = yes;
        self
    }

    /// Attach a cancellation handle (shared with whoever may fire it).
    pub fn with_cancel(mut self, cancel: &CancelToken) -> Evaluator<'a> {
        self.cancel = cancel.clone();
        self
    }

    /// Snapshot the evaluator's cumulative memo counters.
    pub fn memo_stats(&self) -> MemoStats {
        self.ctx.stats()
    }

    pub fn remaining(&self) -> usize {
        if self.cancel.is_cancelled() {
            return 0;
        }
        self.budget - self.evaluated.min(self.budget)
    }

    /// The problem under optimization (for external [`Strategy`] impls).
    pub fn problem(&self) -> &'a OptProblem<'a> {
        self.problem
    }

    pub fn best(&self) -> &[f64] {
        &self.best
    }

    /// Evaluate a batch: cached genomes are free, fresh genomes spend
    /// budget (first-come within the batch) and genomes beyond the budget
    /// come back `None`.  One predict call per batch, dataflow evaluation
    /// over the thread pool — the same pipeline shape as a sweep shard.
    pub fn eval_batch(
        &mut self,
        genomes: &[Genome],
    ) -> Result<Vec<Option<EvalRecord>>, QappaError> {
        let mut fresh: Vec<Genome> = Vec::new();
        let mut fresh_keys: HashMap<Vec<u32>, usize> = HashMap::new();
        let mut plan: Vec<Slot> = Vec::with_capacity(genomes.len());
        let budget_left = self.remaining();
        for g in genomes {
            let key = g.key();
            if self.cache.contains_key(&key) {
                plan.push(Slot::Cached(key));
                continue;
            }
            // copy the index out so the map borrow ends before the insert
            let dup = fresh_keys.get(&key).copied();
            if let Some(i) = dup {
                plan.push(Slot::Fresh(i));
            } else if fresh.len() >= budget_left {
                plan.push(Slot::Skipped);
            } else {
                fresh_keys.insert(key, fresh.len());
                plan.push(Slot::Fresh(fresh.len()));
                fresh.push(g.clone());
            }
        }

        let mut records: Vec<EvalRecord> = Vec::with_capacity(fresh.len());
        if !fresh.is_empty() {
            let t0 = std::time::Instant::now();
            let decoded: Vec<(AcceleratorConfig, Vec<Layer>)> =
                fresh.iter().map(|g| self.problem.search.decode(g)).collect();
            let cfgs: Vec<AcceleratorConfig> = decoded.iter().map(|(c, _)| *c).collect();
            // Populations mix PE recipes, so the SoA predict groups them
            // into per-recipe batches (bit-identical, see sweep.rs).
            let ppas = if self.legacy {
                predict_configs_legacy(self.backend, self.model, &cfgs)?
            } else {
                predict_configs_soa(self.backend, self.model, &cfgs)?
            };
            // Fast path: memoized synthesis derivation + per-genome layer
            // dedup up front (synth counters stay deterministic: the memo
            // is touched sequentially here, never inside the thread pool).
            let prepared: Vec<Option<(EnergyParams, PreparedWorkload)>> = if self.legacy {
                decoded.iter().map(|_| None).collect()
            } else {
                decoded
                    .iter()
                    .map(|(c, l)| {
                        Some((self.ctx.synth.energy_params_with(c), PreparedWorkload::new(l)))
                    })
                    .collect()
            };
            let items: Vec<(AcceleratorConfig, Ppa, Vec<Layer>, Option<(EnergyParams, PreparedWorkload)>)> =
                decoded
                    .into_iter()
                    .zip(ppas)
                    .zip(prepared)
                    .map(|(((c, l), p), pr)| (c, p, l, pr))
                    .collect();
            let workers = workers_for(items.len(), self.workers, 4);
            let ctx = &self.ctx;
            let pts: Vec<DsePoint> =
                parallel_map(&items, workers, |(cfg, ppa, layers, pr)| match pr {
                    Some((ep, prep)) => eval_point_prepared(cfg, *ppa, *ep, prep, ctx),
                    None => eval_point(cfg, *ppa, layers),
                });
            phase_with(|| format!("opt/eval_batch({})", pts.len()), t0);
            obs::registry()
                .histogram("opt.eval_batch_ms")
                .record_ms(t0.elapsed().as_secs_f64() * 1e3);
            let nobj = self.nobj;
            for ((g, p), (cfg, _, layers, _)) in fresh.iter().zip(pts).zip(items.iter()) {
                // Accuracy is a genome property (precision assignment +
                // model knobs), not a pipeline output — estimate it from
                // the decoded layers' effective specs when the problem
                // asks for it.
                let accuracy = self.acc_model.as_ref().map(|am| {
                    let specs: Vec<QuantSpec> =
                        layers.iter().map(|l| l.effective_quant(cfg)).collect();
                    let (w, d) = self.problem.search.model_mults(g);
                    am.estimate_scaled(layers, &specs, w, d)
                });
                let objs: Vec<f64> = self
                    .problem
                    .objectives
                    .iter()
                    .map(|o| o.value_with(&p, accuracy))
                    .collect();
                let violation = self.problem.constraints.violation(&p)
                    + self.problem.constraints.accuracy_violation(accuracy);
                for k in 0..nobj {
                    if objs[k].is_finite() {
                        self.max_all[k] = self.max_all[k].max(objs[k]);
                    }
                }
                if violation == 0.0 {
                    let mf = self
                        .max_feasible
                        .get_or_insert_with(|| vec![f64::NEG_INFINITY; nobj]);
                    for k in 0..nobj {
                        if objs[k].is_finite() {
                            mf[k] = mf[k].max(objs[k]);
                            self.best[k] = self.best[k].min(objs[k]);
                        }
                    }
                    self.archive.push(&objs, (g.clone(), p.clone(), accuracy));
                }
                let rec = EvalRecord { point: p, objs, violation, accuracy };
                self.cache.insert(g.key(), rec.clone());
                records.push(rec);
            }
            self.evaluated += fresh.len();
        }
        let cached = plan.iter().filter(|s| matches!(s, Slot::Cached(_))).count();
        let reg = obs::registry();
        reg.counter("opt.evaluations").add(fresh.len() as u64);
        reg.counter("opt.cache_hits").add(cached as u64);

        Ok(plan
            .into_iter()
            .map(|slot| match slot {
                Slot::Cached(key) => Some(self.cache[&key].clone()),
                Slot::Fresh(i) => Some(records[i].clone()),
                Slot::Skipped => None,
            })
            .collect())
    }

    /// Fix the reference corner from everything evaluated so far (feasible
    /// maxima when any exist, otherwise all points), with a 25% margin so
    /// later, slightly-worse frontier entries still contribute.  No-op
    /// after the first call: per-generation hypervolumes share one corner.
    pub fn freeze_ref(&mut self) {
        if self.ref_point.is_some() {
            return;
        }
        let base = self.max_feasible.as_ref().unwrap_or(&self.max_all);
        let r = |x: &f64| if x.is_finite() && *x > 0.0 { 1.25 * x } else { 1.0 };
        self.ref_point = Some(base.iter().map(r).collect());
    }

    /// The run's reference corner (freezing it now if needed).
    pub fn ref_point(&mut self) -> Vec<f64> {
        self.freeze_ref();
        self.ref_point.clone().expect("ref point frozen")
    }

    /// Archive hypervolume w.r.t. the fixed reference corner.
    pub fn hypervolume(&mut self) -> f64 {
        let r = self.ref_point();
        self.archive.hypervolume(&r)
    }

    /// Convergence snapshot for the current state.  With no feasible point
    /// seen yet, `best` falls back to the reference corner (the wire
    /// format carries finite numbers only).
    pub fn snapshot(&mut self, generation: usize) -> GenStat {
        let r = self.ref_point();
        let best = self
            .best
            .iter()
            .zip(&r)
            .map(|(&x, &fallback)| if x.is_finite() { x } else { fallback })
            .collect();
        obs::registry().counter("opt.generations").inc();
        GenStat {
            generation,
            evaluated: self.evaluated,
            frontier: self.archive.len(),
            hypervolume: self.hypervolume(),
            best,
        }
    }

    /// Consume the evaluator, returning the archive's payloads.
    fn into_frontier_payloads(self) -> Vec<ArchivePayload> {
        self.archive.into_payloads()
    }
}

// ---------------------------------------------------------------------------
// dominance / ranking helpers (NSGA-II)
// ---------------------------------------------------------------------------

/// Deb's constraint-domination: feasible beats infeasible, less-violating
/// beats more-violating, and among feasible points plain Pareto dominance
/// on the minimized objectives.
pub fn constrained_dominates(a: &EvalRecord, b: &EvalRecord) -> bool {
    if a.violation == 0.0 && b.violation > 0.0 {
        return true;
    }
    if a.violation > 0.0 {
        return b.violation > 0.0 && a.violation < b.violation;
    }
    let mut strictly_less = false;
    for (x, y) in a.objs.iter().zip(&b.objs) {
        if x > y {
            return false;
        }
        if x < y {
            strictly_less = true;
        }
    }
    strictly_less
}

/// Fast non-dominated sort; returns each index's front rank (0 = best).
fn nondominated_ranks(recs: &[&EvalRecord]) -> Vec<usize> {
    let n = recs.len();
    let mut dominated_by = vec![0usize; n];
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in (i + 1)..n {
            if constrained_dominates(recs[i], recs[j]) {
                dominates_list[i].push(j);
                dominated_by[j] += 1;
            } else if constrained_dominates(recs[j], recs[i]) {
                dominates_list[j].push(i);
                dominated_by[i] += 1;
            }
        }
    }
    let mut rank = vec![0usize; n];
    let mut front: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
    let mut level = 0;
    while !front.is_empty() {
        let mut next = Vec::new();
        for &i in &front {
            rank[i] = level;
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        level += 1;
        front = next;
    }
    rank
}

/// Crowding distance per index, computed within each front.
fn crowding_distances(recs: &[&EvalRecord], ranks: &[usize]) -> Vec<f64> {
    let n = recs.len();
    let mut dist = vec![0.0f64; n];
    let nobj = recs.first().map_or(0, |r| r.objs.len());
    let max_rank = ranks.iter().copied().max().unwrap_or(0);
    for level in 0..=max_rank {
        let mut front: Vec<usize> = (0..n).filter(|&i| ranks[i] == level).collect();
        if front.len() <= 2 {
            for &i in &front {
                dist[i] = f64::INFINITY;
            }
            continue;
        }
        for k in 0..nobj {
            front.sort_by(|&a, &b| recs[a].objs[k].total_cmp(&recs[b].objs[k]));
            let lo = recs[front[0]].objs[k];
            let hi = recs[front[front.len() - 1]].objs[k];
            dist[front[0]] = f64::INFINITY;
            dist[front[front.len() - 1]] = f64::INFINITY;
            let span = hi - lo;
            if span <= 0.0 || !span.is_finite() {
                continue;
            }
            for w in 1..front.len() - 1 {
                let gap = recs[front[w + 1]].objs[k] - recs[front[w - 1]].objs[k];
                dist[front[w]] += gap / span;
            }
        }
    }
    dist
}

// ---------------------------------------------------------------------------
// strategies
// ---------------------------------------------------------------------------

/// A search strategy: spends the evaluator's budget, records convergence.
pub trait Strategy {
    fn name(&self) -> &'static str;
    fn run(&self, ev: &mut Evaluator, rng: &mut Rng) -> Result<Vec<GenStat>, QappaError>;
}

/// NSGA-II-style evolutionary search (see the module docs).
pub struct Nsga2 {
    pub pop: usize,
}

impl Nsga2 {
    fn tournament<'p>(
        rng: &mut Rng,
        pop: &'p [(Genome, EvalRecord)],
        ranks: &[usize],
        crowd: &[f64],
    ) -> &'p Genome {
        let i = rng.below(pop.len());
        let j = rng.below(pop.len());
        let win = if ranks[i] != ranks[j] {
            if ranks[i] < ranks[j] { i } else { j }
        } else if crowd[i] != crowd[j] {
            if crowd[i] > crowd[j] { i } else { j }
        } else {
            i
        };
        &pop[win].0
    }

    /// Elitist environmental selection: best `k` of the union by
    /// (rank, crowding), deterministic under ties via the stable index
    /// order.
    fn select_next(
        union: Vec<(Genome, EvalRecord)>,
        k: usize,
    ) -> Vec<(Genome, EvalRecord)> {
        let recs: Vec<&EvalRecord> = union.iter().map(|(_, r)| r).collect();
        let ranks = nondominated_ranks(&recs);
        let crowd = crowding_distances(&recs, &ranks);
        let mut order: Vec<usize> = (0..union.len()).collect();
        order.sort_by(|&a, &b| {
            ranks[a]
                .cmp(&ranks[b])
                .then(crowd[b].total_cmp(&crowd[a]))
                .then(a.cmp(&b))
        });
        order.truncate(k);
        let keep: std::collections::BTreeSet<usize> = order.into_iter().collect();
        union
            .into_iter()
            .enumerate()
            .filter_map(|(i, item)| keep.contains(&i).then_some(item))
            .collect()
    }
}

impl Strategy for Nsga2 {
    fn name(&self) -> &'static str {
        "nsga2"
    }

    fn run(&self, ev: &mut Evaluator, rng: &mut Rng) -> Result<Vec<GenStat>, QappaError> {
        let pop_size = self.pop.max(8);
        // Initial population: deterministic grid-corner anchors per
        // palette cell, random fill for diversity.
        let mut init = ev.problem.search.corner_seeds();
        init.truncate(pop_size);
        while init.len() < pop_size {
            init.push(ev.problem.search.random(rng));
        }
        let recs = ev.eval_batch(&init)?;
        let mut pop: Vec<(Genome, EvalRecord)> = init
            .into_iter()
            .zip(recs)
            .filter_map(|(g, r)| r.map(|r| (g, r)))
            .collect();
        ev.freeze_ref();
        let mut stats = vec![ev.snapshot(0)];
        if pop.is_empty() {
            return Ok(stats);
        }

        let mut generation = 0usize;
        let mut stall = 0usize;
        while ev.remaining() > 0 && stall < 5 {
            generation += 1;
            let spent_before = ev.evaluated;
            let recs: Vec<&EvalRecord> = pop.iter().map(|(_, r)| r).collect();
            let ranks = nondominated_ranks(&recs);
            let crowd = crowding_distances(&recs, &ranks);
            let mut children: Vec<Genome> = Vec::with_capacity(pop_size);
            while children.len() < pop_size {
                let a = Self::tournament(rng, &pop, &ranks, &crowd).clone();
                let b = Self::tournament(rng, &pop, &ranks, &crowd).clone();
                let (mut c1, mut c2) = if rng.f64() < 0.9 {
                    ev.problem.search.crossover(&a, &b, rng)
                } else {
                    (a, b)
                };
                ev.problem.search.mutate(&mut c1, rng);
                ev.problem.search.mutate(&mut c2, rng);
                children.push(c1);
                if children.len() < pop_size {
                    children.push(c2);
                }
            }
            let child_recs = ev.eval_batch(&children)?;
            let mut union = pop;
            union.extend(
                children
                    .into_iter()
                    .zip(child_recs)
                    .filter_map(|(g, r)| r.map(|r| (g, r))),
            );
            pop = Self::select_next(union, pop_size);
            stats.push(ev.snapshot(generation));
            if ev.evaluated == spent_before {
                stall += 1; // a whole generation of cache hits
            } else {
                stall = 0;
            }
        }
        Ok(stats)
    }
}

/// Uniform random sampling at the same budget — the baseline every guided
/// strategy has to beat.
pub struct RandomSearch {
    pub batch: usize,
}

impl Strategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn run(&self, ev: &mut Evaluator, rng: &mut Rng) -> Result<Vec<GenStat>, QappaError> {
        let batch = self.batch.max(8);
        let mut stats = Vec::new();
        let mut round = 0usize;
        let mut stall = 0usize;
        while ev.remaining() > 0 && stall < 5 {
            let spent_before = ev.evaluated;
            let genomes: Vec<Genome> = (0..batch.min(ev.remaining().max(1)))
                .map(|_| ev.problem.search.random(rng))
                .collect();
            ev.eval_batch(&genomes)?;
            ev.freeze_ref();
            stats.push(ev.snapshot(round));
            round += 1;
            if ev.evaluated == spent_before {
                stall += 1; // the whole batch was already cached
            } else {
                stall = 0;
            }
        }
        Ok(stats)
    }
}

/// Restarted hill climbing: each restart scalarizes the objectives with a
/// random weight vector, then walks ±1-step hardware neighbors (plus a few
/// precision tweaks) as long as the scalar improves.
pub struct HillClimb {
    pub batch: usize,
}

impl HillClimb {
    /// A random point on the weight simplex: gap lengths between `n - 1`
    /// sorted uniform cuts of [0, 1].  For two objectives this is a single
    /// `rng.f64()` draw yielding `[w, 1 - w]` — the exact pre-3-objective
    /// stream, so seeded two-objective runs are unchanged.
    fn weights(n: usize, rng: &mut Rng) -> Vec<f64> {
        let mut cuts: Vec<f64> = (0..n.saturating_sub(1)).map(|_| rng.f64()).collect();
        cuts.sort_by(|a, b| a.total_cmp(b));
        let mut w = Vec::with_capacity(n);
        let mut prev = 0.0;
        for c in cuts {
            w.push(c - prev);
            prev = c;
        }
        w.push(1.0 - prev);
        w
    }

    fn score(rec: &EvalRecord, w: &[f64], r: &[f64]) -> f64 {
        if rec.violation > 0.0 {
            return 1e12 * (1.0 + rec.violation);
        }
        w.iter()
            .zip(&rec.objs)
            .zip(r)
            .map(|((wi, o), ri)| wi * o / ri)
            .sum()
    }

    fn neighbors(search: &SearchSpace, g: &Genome, rng: &mut Rng) -> Vec<Genome> {
        let lens = search.axis_lens();
        let mut out = Vec::new();
        for i in 0..lens.len() {
            if g.hw[i] > 0 {
                let mut n = g.clone();
                n.hw[i] -= 1;
                out.push(n);
            }
            if g.hw[i] + 1 < lens[i] {
                let mut n = g.clone();
                n.hw[i] += 1;
                out.push(n);
            }
        }
        if let Some(mk) = &search.model {
            let mlens = [mk.width.len(), mk.depth.len()];
            for i in 0..g.model.len().min(2) {
                if g.model[i] > 0 {
                    let mut n = g.clone();
                    n.model[i] -= 1;
                    out.push(n);
                }
                if g.model[i] + 1 < mlens[i] {
                    let mut n = g.clone();
                    n.model[i] += 1;
                    out.push(n);
                }
            }
        }
        let pal = search.palette.len();
        if pal > 1 {
            for _ in 0..4usize.min(g.prec.len()) {
                let mut n = g.clone();
                let i = rng.below(n.prec.len());
                n.prec[i] = rng.below(pal);
                out.push(n);
            }
        }
        out
    }
}

impl Strategy for HillClimb {
    fn name(&self) -> &'static str {
        "hillclimb"
    }

    fn run(&self, ev: &mut Evaluator, rng: &mut Rng) -> Result<Vec<GenStat>, QappaError> {
        // Seed batch fixes the reference corner and provides start points.
        let mut seeds = ev.problem.search.corner_seeds();
        seeds.truncate(self.batch.max(8));
        while seeds.len() < self.batch.max(8) {
            seeds.push(ev.problem.search.random(rng));
        }
        let seed_recs = ev.eval_batch(&seeds)?;
        ev.freeze_ref();
        let r = ev.ref_point();
        let mut stats = vec![ev.snapshot(0)];
        let mut restart = 0usize;
        let mut pool: Vec<(Genome, EvalRecord)> = seeds
            .into_iter()
            .zip(seed_recs)
            .filter_map(|(g, rec)| rec.map(|rec| (g, rec)))
            .collect();
        if pool.is_empty() {
            return Ok(stats);
        }
        let mut stall = 0usize;
        while ev.remaining() > 0 && stall < 5 {
            restart += 1;
            let spent_before = ev.evaluated;
            let w = Self::weights(ev.problem.objectives.len(), rng);
            // start from the pool's best under this restart's weights
            let (mut cur_g, mut cur_rec) = pool
                .iter()
                .min_by(|a, b| {
                    Self::score(&a.1, &w, &r).total_cmp(&Self::score(&b.1, &w, &r))
                })
                .cloned()
                .expect("non-empty pool");
            loop {
                let neigh = Self::neighbors(&ev.problem.search, &cur_g, rng);
                if neigh.is_empty() || ev.remaining() == 0 {
                    break;
                }
                let recs = ev.eval_batch(&neigh)?;
                let mut best: Option<(usize, f64)> = None;
                for (i, rec) in recs.iter().enumerate() {
                    if let Some(rec) = rec {
                        let s = Self::score(rec, &w, &r);
                        let better = match best {
                            None => true,
                            Some((_, bs)) => s < bs,
                        };
                        if better {
                            best = Some((i, s));
                        }
                    }
                }
                match best {
                    Some((i, s)) if s < Self::score(&cur_rec, &w, &r) => {
                        cur_g = neigh[i].clone();
                        cur_rec = recs[i].clone().expect("scored record exists");
                    }
                    _ => break, // local optimum under these weights
                }
            }
            pool.push((cur_g, cur_rec));
            stats.push(ev.snapshot(restart));
            if ev.remaining() > 0 {
                // diversify the pool with a fresh random start
                let g = ev.problem.search.random(rng);
                if let Some(rec) = ev.eval_batch(std::slice::from_ref(&g))?.remove(0) {
                    pool.push((g, rec));
                }
            }
            if ev.evaluated == spent_before {
                stall += 1; // a whole restart of cache hits: domain exhausted
            } else {
                stall = 0;
            }
        }
        Ok(stats)
    }
}

// ---------------------------------------------------------------------------
// entry point
// ---------------------------------------------------------------------------

/// Run one guided search: dispatch the configured strategy, then lift the
/// evaluator's archive into the sorted frontier report.
pub fn run_optimize(
    backend: &dyn Backend,
    model: &PpaModel,
    problem: &OptProblem,
    opts: &OptOptions,
    workers: usize,
) -> Result<OptResult, QappaError> {
    run_optimize_cancellable(backend, model, problem, opts, workers, &CancelToken::new())
}

/// [`run_optimize`] with a cooperative [`CancelToken`]: when the token
/// fires the strategies exit at their next batch boundary and the partial
/// archive is lifted into an ordinary (smaller) result — the caller decides
/// whether a cancelled partial answer is an error.
pub fn run_optimize_cancellable(
    backend: &dyn Backend,
    model: &PpaModel,
    problem: &OptProblem,
    opts: &OptOptions,
    workers: usize,
    cancel: &CancelToken,
) -> Result<OptResult, QappaError> {
    if opts.budget == 0 {
        return Err(QappaError::Config("optimize: budget must be >= 1".into()));
    }
    if !(2..=3).contains(&problem.objectives.len()) {
        return Err(QappaError::Config(format!(
            "optimize: exactly two or three objectives are required, got {}",
            problem.objectives.len()
        )));
    }
    problem.constraints.validate()?;
    let mut ev = Evaluator::new(backend, model, problem, workers, opts.budget)
        .legacy(opts.legacy_eval || legacy_eval_env())
        .with_cancel(cancel);
    let mut rng = Rng::new(opts.seed);
    let strategy: Box<dyn Strategy> = match opts.strategy {
        StrategyKind::Nsga2 => Box::new(Nsga2 { pop: opts.pop }),
        StrategyKind::Random => Box::new(RandomSearch { batch: opts.pop }),
        StrategyKind::HillClimb => Box::new(HillClimb { batch: opts.pop.min(16) }),
    };
    let generations = strategy.run(&mut ev, &mut rng)?;
    let ref_point = ev.ref_point();
    let hypervolume = ev.hypervolume();
    let evaluated = ev.evaluated;
    let memo = ev.memo_stats();
    let mut frontier: Vec<FrontierPoint> = ev
        .into_frontier_payloads()
        .into_iter()
        .map(|(genome, point, accuracy)| {
            let objs: Vec<f64> = problem
                .objectives
                .iter()
                .map(|o| o.value_with(&point, accuracy))
                .collect();
            let precision = problem.search.precision_labels(&genome);
            FrontierPoint { genome, point, objs, precision, accuracy }
        })
        .collect();
    frontier.sort_by(|a, b| {
        let mut ord = std::cmp::Ordering::Equal;
        for (x, y) in a.objs.iter().zip(&b.objs) {
            ord = ord.then(x.total_cmp(y));
        }
        ord
    });
    let reg = obs::registry();
    reg.counter("opt.runs").inc();
    reg.gauge("opt.last_hypervolume").set(hypervolume);
    Ok(OptResult {
        strategy: strategy.name(),
        evaluated,
        ref_point,
        hypervolume,
        frontier,
        generations,
        memo,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ALL_PE_TYPES, QUANT_NUM_FEATURES};
    use crate::coordinator::explorer::{DseOptions, ModelStore};
    use crate::coordinator::space::DesignSpace;
    use crate::model::native::NativeBackend;
    use crate::model::CvConfig;

    fn tiny_opts() -> DseOptions {
        DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 96,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
            seed: 7,
            workers: 4,
            sigma: 0.02,
            chunk: 64,
            topk: 4,
        }
    }

    fn layers() -> Vec<Layer> {
        vec![
            Layer::conv("c1", 3, 16, 32, 32, 3, 1, 1),
            Layer::dw("dw", 16, 16, 3, 1, 1),
            Layer::pw("pw", 16, 32, 16),
            Layer::fc("fc", 512, 10),
        ]
    }

    fn setup() -> (NativeBackend, ModelStore, DseOptions) {
        (NativeBackend::new(QUANT_NUM_FEATURES), ModelStore::new(), tiny_opts())
    }

    fn run(
        backend: &NativeBackend,
        model: &PpaModel,
        opts: &DseOptions,
        ls: &[Layer],
        oopts: &OptOptions,
        constraints: Constraints,
    ) -> OptResult {
        let search =
            SearchSpace::new(&opts.space, ALL_PE_TYPES.to_vec(), ls, true).unwrap();
        let problem = OptProblem {
            search,
            objectives: vec![Objective::PerfPerArea, Objective::Energy],
            accuracy: None,
            constraints,
        };
        run_optimize(backend, model, &problem, oopts, opts.workers).unwrap()
    }

    #[test]
    fn cancelled_token_stops_a_run_before_any_evaluation() {
        let (backend, store, opts) = setup();
        let model = store
            .get_or_train_quant(&backend, &opts, &ALL_PE_TYPES.to_vec())
            .unwrap();
        let ls = layers();
        let search =
            SearchSpace::new(&opts.space, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        let problem = OptProblem {
            search,
            objectives: vec![Objective::PerfPerArea, Objective::Energy],
            accuracy: None,
            constraints: Constraints::default(),
        };
        let oopts = OptOptions {
            strategy: StrategyKind::Nsga2,
            budget: 120,
            pop: 24,
            seed: 5,
            ..Default::default()
        };
        let cancel = CancelToken::new();
        cancel.cancel();
        // Already-fired token: the run returns an ordinary (empty) result
        // without spending a single evaluation — the batch planner sees
        // remaining() == 0 and skips everything.
        let r = run_optimize_cancellable(
            &backend, &model, &problem, &oopts, opts.workers, &cancel,
        )
        .unwrap();
        assert_eq!(r.evaluated, 0);
        assert!(r.frontier.is_empty());
    }

    #[test]
    fn nsga2_respects_budget_and_is_seed_deterministic() {
        let (backend, store, opts) = setup();
        let model = store
            .get_or_train_quant(&backend, &opts, &ALL_PE_TYPES.to_vec())
            .unwrap();
        let ls = layers();
        let oopts = OptOptions {
            strategy: StrategyKind::Nsga2,
            budget: 120,
            pop: 24,
            seed: 5,
            ..Default::default()
        };
        let a = run(&backend, &model, &opts, &ls, &oopts, Constraints::default());
        assert!(a.evaluated <= 120, "budget exceeded: {}", a.evaluated);
        assert!(a.evaluated >= 20, "initial population must be evaluated");
        assert!(!a.frontier.is_empty());
        assert!(a.hypervolume > 0.0);
        assert!(!a.generations.is_empty());
        // convergence stats are monotone in spend and hypervolume
        for w in a.generations.windows(2) {
            assert!(w[1].evaluated >= w[0].evaluated);
            assert!(w[1].hypervolume >= w[0].hypervolume - 1e-12);
        }
        // same seed, bit-identical run
        let b = run(&backend, &model, &opts, &ls, &oopts, Constraints::default());
        assert_eq!(a.evaluated, b.evaluated);
        assert_eq!(a.hypervolume, b.hypervolume);
        assert_eq!(a.frontier.len(), b.frontier.len());
        for (x, y) in a.frontier.iter().zip(&b.frontier) {
            assert_eq!(x.genome, y.genome);
            assert_eq!(x.objs, y.objs);
            assert_eq!(x.point.cfg, y.point.cfg);
        }
        // a different seed explores differently
        let c = run(
            &backend,
            &model,
            &opts,
            &ls,
            &OptOptions { seed: 6, ..oopts },
            Constraints::default(),
        );
        assert!(
            c.hypervolume != a.hypervolume || c.evaluated != a.evaluated
                || c.frontier.len() != a.frontier.len()
        );
    }

    #[test]
    fn frontier_is_mutually_nondominated_and_sorted() {
        let (backend, store, opts) = setup();
        let model = store
            .get_or_train_quant(&backend, &opts, &ALL_PE_TYPES.to_vec())
            .unwrap();
        let ls = layers();
        let oopts = OptOptions {
            strategy: StrategyKind::Nsga2,
            budget: 100,
            pop: 20,
            seed: 3,
            ..Default::default()
        };
        let res = run(&backend, &model, &opts, &ls, &oopts, Constraints::default());
        for (i, a) in res.frontier.iter().enumerate() {
            for (j, b) in res.frontier.iter().enumerate() {
                if i == j {
                    continue;
                }
                let dom = a.objs[0] <= b.objs[0]
                    && a.objs[1] <= b.objs[1]
                    && (a.objs[0] < b.objs[0] || a.objs[1] < b.objs[1]);
                assert!(!dom, "frontier member {j} dominated by {i}");
            }
        }
        for w in res.frontier.windows(2) {
            assert!(w[0].objs[0] <= w[1].objs[0], "frontier sorted by objective 0");
        }
    }

    #[test]
    fn constraints_exclude_infeasible_points_from_the_frontier() {
        let (backend, store, opts) = setup();
        let model = store
            .get_or_train_quant(&backend, &opts, &ALL_PE_TYPES.to_vec())
            .unwrap();
        let ls = layers();
        let oopts = OptOptions {
            strategy: StrategyKind::Nsga2,
            budget: 100,
            pop: 20,
            seed: 9,
            ..Default::default()
        };
        // unconstrained run to pick a binding area bound
        let free = run(&backend, &model, &opts, &ls, &oopts, Constraints::default());
        let areas: Vec<f64> = free.frontier.iter().map(|f| f.point.ppa.area_mm2).collect();
        let max_area = areas.iter().cloned().fold(f64::MIN, f64::max);
        let min_area = areas.iter().cloned().fold(f64::MAX, f64::min);
        let bound = 0.5 * (min_area + max_area);
        let constrained = run(
            &backend,
            &model,
            &opts,
            &ls,
            &oopts,
            Constraints { max_area_mm2: Some(bound), ..Default::default() },
        );
        assert!(!constrained.frontier.is_empty());
        for f in &constrained.frontier {
            assert!(
                f.point.ppa.area_mm2 <= bound,
                "frontier point violates area bound: {} > {bound}",
                f.point.ppa.area_mm2
            );
        }
        // an impossible bound yields an empty frontier, not a panic
        let impossible = run(
            &backend,
            &model,
            &opts,
            &ls,
            &oopts,
            Constraints { max_area_mm2: Some(1e-6), ..Default::default() },
        );
        assert!(impossible.frontier.is_empty());
        assert_eq!(impossible.hypervolume, 0.0);
    }

    #[test]
    fn all_strategies_run_behind_the_common_trait() {
        let (backend, store, opts) = setup();
        let model = store
            .get_or_train_quant(&backend, &opts, &ALL_PE_TYPES.to_vec())
            .unwrap();
        let ls = layers();
        for kind in [StrategyKind::Nsga2, StrategyKind::Random, StrategyKind::HillClimb] {
            let oopts =
                OptOptions { strategy: kind, budget: 60, pop: 16, seed: 13, ..Default::default() };
            let res = run(&backend, &model, &opts, &ls, &oopts, Constraints::default());
            assert_eq!(res.strategy, kind.label());
            assert!(res.evaluated <= 60, "{:?}", kind);
            assert!(!res.frontier.is_empty(), "{:?}", kind);
            assert!(res.hypervolume > 0.0, "{:?}", kind);
        }
        // strategy parsing round-trips
        for kind in [StrategyKind::Nsga2, StrategyKind::Random, StrategyKind::HillClimb] {
            assert_eq!(StrategyKind::parse(kind.label()).unwrap(), kind);
        }
        assert!(StrategyKind::parse("sa").is_err());
    }

    #[test]
    fn budget_zero_and_bad_constraints_are_config_errors() {
        let (backend, store, opts) = setup();
        let model = store
            .get_or_train_quant(&backend, &opts, &ALL_PE_TYPES.to_vec())
            .unwrap();
        let ls = layers();
        let search =
            SearchSpace::new(&opts.space, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        let problem = OptProblem {
            search,
            objectives: vec![Objective::PerfPerArea, Objective::Energy],
            accuracy: None,
            constraints: Constraints::default(),
        };
        let e = run_optimize(
            &backend,
            &model,
            &problem,
            &OptOptions { budget: 0, ..Default::default() },
            2,
        )
        .unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.to_string().contains("budget"), "{e}");
        let search =
            SearchSpace::new(&opts.space, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        let problem = OptProblem {
            search,
            objectives: vec![Objective::PerfPerArea, Objective::Energy],
            accuracy: None,
            constraints: Constraints { max_power_mw: Some(-3.0), ..Default::default() },
        };
        let e = run_optimize(&backend, &model, &problem, &OptOptions::default(), 2)
            .unwrap_err();
        assert!(e.to_string().contains("max_power_mw"), "{e}");
    }

    #[test]
    fn memoized_search_bit_identical_to_legacy_and_reports_memo() {
        // The memoized SoA pipeline must reproduce the legacy per-point
        // run bit-for-bit (same seed, same budget): same spend, same
        // hypervolume, same frontier genomes/objectives/configs.
        let (backend, store, opts) = setup();
        let model = store
            .get_or_train_quant(&backend, &opts, &ALL_PE_TYPES.to_vec())
            .unwrap();
        let ls = layers();
        for kind in [StrategyKind::Nsga2, StrategyKind::Random, StrategyKind::HillClimb] {
            let fast_opts =
                OptOptions { strategy: kind, budget: 80, pop: 16, seed: 21, ..Default::default() };
            let slow_opts = OptOptions { legacy_eval: true, ..fast_opts };
            let fast = run(&backend, &model, &opts, &ls, &fast_opts, Constraints::default());
            let slow = run(&backend, &model, &opts, &ls, &slow_opts, Constraints::default());
            assert_eq!(fast.evaluated, slow.evaluated, "{kind:?}");
            assert_eq!(
                fast.hypervolume.to_bits(),
                slow.hypervolume.to_bits(),
                "{kind:?}"
            );
            assert_eq!(fast.ref_point[0].to_bits(), slow.ref_point[0].to_bits());
            assert_eq!(fast.ref_point[1].to_bits(), slow.ref_point[1].to_bits());
            assert_eq!(fast.frontier.len(), slow.frontier.len(), "{kind:?}");
            for (x, y) in fast.frontier.iter().zip(&slow.frontier) {
                assert_eq!(x.genome, y.genome, "{kind:?}");
                assert_eq!(x.objs[0].to_bits(), y.objs[0].to_bits(), "{kind:?}");
                assert_eq!(x.objs[1].to_bits(), y.objs[1].to_bits(), "{kind:?}");
                assert_eq!(x.point.cfg, y.point.cfg, "{kind:?}");
            }
            assert_eq!(fast.generations, slow.generations, "{kind:?}");
            // The fast run exercised the memo; the legacy run never did.
            assert!(
                fast.memo.synth_hits + fast.memo.synth_misses > 0,
                "{kind:?}: memo untouched"
            );
            assert_eq!(slow.memo, MemoStats::default(), "{kind:?}");
        }
    }

    #[test]
    fn three_objective_accuracy_run_is_seeded_and_respects_the_floor() {
        let (backend, store, opts) = setup();
        let model = store
            .get_or_train_quant(&backend, &opts, &ALL_PE_TYPES.to_vec())
            .unwrap();
        let ls = layers();
        let run3 = |seed: u64, constraints: Constraints| {
            let search =
                SearchSpace::new(&opts.space, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
            let problem = OptProblem {
                search,
                objectives: vec![Objective::Latency, Objective::Energy, Objective::Accuracy],
                constraints,
                accuracy: None, // structural proxy fallback
            };
            let oopts = OptOptions {
                strategy: StrategyKind::Nsga2,
                budget: 90,
                pop: 16,
                seed,
                ..Default::default()
            };
            run_optimize(&backend, &model, &problem, &oopts, opts.workers).unwrap()
        };
        let a = run3(5, Constraints::default());
        assert_eq!(a.ref_point.len(), 3);
        assert!(!a.frontier.is_empty());
        assert!(a.hypervolume > 0.0);
        for f in &a.frontier {
            assert_eq!(f.objs.len(), 3);
            let acc = f.accuracy.expect("accuracy-aware run records accuracy");
            assert!((0.0..=1.0).contains(&acc));
            assert!((f.objs[2] - (1.0 - acc)).abs() < 1e-12);
        }
        // accuracy actually discriminates: the frontier spans precisions
        let accs: Vec<u64> = a.frontier.iter().map(|f| f.accuracy.unwrap().to_bits()).collect();
        assert!(accs.iter().any(|&x| x != accs[0]), "frontier accuracy is constant");
        // bit-identical under the same seed
        let b = run3(5, Constraints::default());
        assert_eq!(a.evaluated, b.evaluated);
        assert_eq!(a.hypervolume.to_bits(), b.hypervolume.to_bits());
        assert_eq!(a.frontier.len(), b.frontier.len());
        for (x, y) in a.frontier.iter().zip(&b.frontier) {
            assert_eq!(x.genome, y.genome);
            assert_eq!(x.objs, y.objs);
        }
        // a min-accuracy floor is never violated in the returned frontier
        let floored = run3(5, Constraints { min_accuracy: Some(0.95), ..Default::default() });
        for f in &floored.frontier {
            assert!(f.accuracy.unwrap() >= 0.95, "floor violated: {:?}", f.accuracy);
        }
    }

    #[test]
    fn nondominated_sort_and_crowding_are_sane() {
        fn rec(o0: f64, o1: f64, v: f64) -> EvalRecord {
            let cfg = crate::config::AcceleratorConfig::default_with(
                crate::config::PeType::Int16,
            );
            EvalRecord {
                point: DsePoint {
                    cfg,
                    ppa: Ppa { power_mw: 1.0, fmax_mhz: 1.0, area_mm2: 1.0 },
                    throughput: 1.0,
                    perf_per_area: 1.0,
                    energy_mj: 1.0,
                    utilization: 1.0,
                },
                objs: vec![o0, o1],
                violation: v,
                accuracy: None,
            }
        }
        // feasible dominates infeasible; violation orders infeasible
        assert!(constrained_dominates(&rec(9.0, 9.0, 0.0), &rec(1.0, 1.0, 0.5)));
        assert!(constrained_dominates(&rec(9.0, 9.0, 0.1), &rec(1.0, 1.0, 0.5)));
        assert!(!constrained_dominates(&rec(1.0, 1.0, 0.5), &rec(9.0, 9.0, 0.0)));
        // feasible Pareto semantics
        assert!(constrained_dominates(&rec(1.0, 1.0, 0.0), &rec(1.0, 2.0, 0.0)));
        assert!(!constrained_dominates(&rec(1.0, 1.0, 0.0), &rec(1.0, 1.0, 0.0)));
        let pool = [
            rec(1.0, 4.0, 0.0), // front 0
            rec(2.0, 2.0, 0.0), // front 0
            rec(4.0, 1.0, 0.0), // front 0
            rec(3.0, 3.0, 0.0), // dominated by (2,2): front 1
            rec(0.0, 0.0, 2.0), // infeasible: ranked below feasible
        ];
        let refs: Vec<&EvalRecord> = pool.iter().collect();
        let ranks = nondominated_ranks(&refs);
        assert_eq!(ranks[0], 0);
        assert_eq!(ranks[1], 0);
        assert_eq!(ranks[2], 0);
        assert_eq!(ranks[3], 1);
        assert!(ranks[4] > ranks[3], "infeasible ranks below every feasible front");
        let crowd = crowding_distances(&refs, &ranks);
        // boundary members of the first front are infinitely crowded
        assert!(crowd[0].is_infinite());
        assert!(crowd[2].is_infinite());
        assert!(crowd[1].is_finite() && crowd[1] > 0.0);
    }
}
