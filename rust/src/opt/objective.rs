//! Named objectives and hard constraints for the guided optimizer.
//!
//! Every objective is canonicalized to a **minimized** scalar read off the
//! already-evaluated [`DsePoint`] (the `dataflow::evaluate_network` cost
//! struct flows through [`crate::coordinator::sweep::eval_point`]), so the
//! search engine never needs to know which direction a metric improves in:
//! smaller is always better.  Pareto dominance is invariant under the
//! per-objective monotone transforms used here (e.g. `perf/area` is
//! minimized as its reciprocal), so the frontier the engine reports is the
//! frontier of the raw metrics.
//!
//! Constraints are *hard*: a point violating any of them is excluded from
//! the frontier archive outright, and NSGA-II ranks infeasible points below
//! every feasible one (Deb's constraint-domination), ordered by total
//! normalized violation so the population still climbs toward feasibility.
//! `min_bits` is a genome-level constraint — it prunes the precision
//! palette before the search starts rather than penalizing evaluations
//! (see [`crate::api::session::Qappa::optimize`]).
//!
//! Two to three objectives are supported.  [`Objective::Accuracy`] is the
//! odd one out: it is a property of the *genome* (per-layer precision +
//! model knobs, estimated by [`crate::accuracy::AccuracyModel`]), not of
//! the evaluated [`DsePoint`], so the engine supplies it separately via
//! [`Objective::value_with`]; `min_accuracy` is likewise checked through
//! [`Constraints::accuracy_violation`].

use crate::api::error::QappaError;
use crate::coordinator::explorer::DsePoint;

/// A named optimization objective, canonicalized to minimize.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// End-to-end latency, seconds per inference.
    Latency,
    /// Energy per inference, mJ.
    Energy,
    /// Array area, mm².
    Area,
    /// Array power, mW.
    Power,
    /// Throughput per mm², minimized as its reciprocal.
    PerfPerArea,
    /// Throughput per mJ, minimized as its reciprocal (numerically the
    /// energy-delay product — `1 / (perf/energy) = energy x latency`).
    PerfPerEnergy,
    /// Energy-delay product, mJ·s.
    Edp,
    /// Estimated network accuracy (maximize), minimized as `1 - accuracy`.
    /// Computed from the genome's per-layer precisions and model knobs by
    /// [`crate::accuracy::AccuracyModel`], not from the `DsePoint`.
    Accuracy,
}

/// Every objective, in help/docs order.
pub const ALL_OBJECTIVES: [Objective; 8] = [
    Objective::Latency,
    Objective::Energy,
    Objective::Area,
    Objective::Power,
    Objective::PerfPerArea,
    Objective::PerfPerEnergy,
    Objective::Edp,
    Objective::Accuracy,
];

impl Objective {
    /// Canonical name (the wire/CLI identity).
    pub fn label(self) -> &'static str {
        match self {
            Objective::Latency => "latency",
            Objective::Energy => "energy",
            Objective::Area => "area",
            Objective::Power => "power",
            Objective::PerfPerArea => "perf/area",
            Objective::PerfPerEnergy => "perf/energy",
            Objective::Edp => "edp",
            Objective::Accuracy => "accuracy",
        }
    }

    /// True for the one objective read off the genome's accuracy estimate
    /// instead of the evaluated `DsePoint`.
    pub fn needs_accuracy(self) -> bool {
        matches!(self, Objective::Accuracy)
    }

    /// Parse a name or alias, case-insensitively.  Unknown names are
    /// config errors listing the vocabulary.
    pub fn parse(s: &str) -> Result<Objective, QappaError> {
        match s.to_ascii_lowercase().as_str() {
            "latency" | "lat" => Ok(Objective::Latency),
            "energy" => Ok(Objective::Energy),
            "area" => Ok(Objective::Area),
            "power" => Ok(Objective::Power),
            "perf/area" | "perf_per_area" | "perfarea" => Ok(Objective::PerfPerArea),
            "perf/energy" | "perf_per_energy" | "perfenergy" => Ok(Objective::PerfPerEnergy),
            "edp" => Ok(Objective::Edp),
            "accuracy" | "acc" => Ok(Objective::Accuracy),
            other => Err(QappaError::Config(format!(
                "unknown objective '{other}' (expected {})",
                ALL_OBJECTIVES.map(|o| o.label()).join("|")
            ))),
        }
    }

    /// The minimized scalar for one evaluated design point.
    /// [`Objective::Accuracy`] cannot be read off a `DsePoint`; the engine
    /// routes it through [`Objective::value_with`].
    pub fn value(self, p: &DsePoint) -> f64 {
        let latency_s = 1.0 / p.throughput.max(1e-300);
        match self {
            Objective::Latency => latency_s,
            Objective::Energy => p.energy_mj,
            Objective::Area => p.ppa.area_mm2,
            Objective::Power => p.ppa.power_mw,
            Objective::PerfPerArea => 1.0 / p.perf_per_area.max(1e-300),
            Objective::PerfPerEnergy | Objective::Edp => p.energy_mj * latency_s,
            Objective::Accuracy => {
                debug_assert!(false, "Accuracy must be scored via value_with");
                1.0
            }
        }
    }

    /// The minimized scalar with the genome's accuracy estimate supplied.
    /// `Accuracy` minimizes `1 - accuracy`; a missing estimate scores as
    /// the worst case (accuracy 0) so a wiring bug can never look optimal.
    pub fn value_with(self, p: &DsePoint, accuracy: Option<f64>) -> f64 {
        match self {
            Objective::Accuracy => 1.0 - accuracy.unwrap_or(0.0),
            other => other.value(p),
        }
    }
}

/// Resolve a list of objective names into the engine's form: two or three
/// distinct objectives.  An empty list means the paper's classic pair
/// (perf/area, energy).
pub fn resolve_objectives(names: &[String]) -> Result<Vec<Objective>, QappaError> {
    if names.is_empty() {
        return Ok(vec![Objective::PerfPerArea, Objective::Energy]);
    }
    if !(2..=3).contains(&names.len()) {
        return Err(QappaError::Config(format!(
            "optimize: exactly two or three objectives are required (got {}); \
             available: {}",
            names.len(),
            ALL_OBJECTIVES.map(|o| o.label()).join(", ")
        )));
    }
    let objs: Vec<Objective> =
        names.iter().map(|n| Objective::parse(n)).collect::<Result<_, _>>()?;
    // Distinct by *value*, not just by name: `perf/energy` and `edp`
    // minimize the same scalar, so pairing them would silently collapse
    // the search into fewer objectives.
    let canonical = |o: Objective| match o {
        Objective::Edp => Objective::PerfPerEnergy,
        other => other,
    };
    for i in 0..objs.len() {
        for j in i + 1..objs.len() {
            if canonical(objs[i]) == canonical(objs[j]) {
                return Err(QappaError::Config(format!(
                    "optimize: objectives must be distinct (got '{}' and '{}', which \
                     minimize the same quantity)",
                    objs[i].label(),
                    objs[j].label()
                )));
            }
        }
    }
    Ok(objs)
}

/// Hard constraints on the search.  `max_*` bounds are evaluated on each
/// design point; `min_bits` prunes the precision palette up front.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Constraints {
    /// `area_mm2 <= X` on the (predicted) array area.
    pub max_area_mm2: Option<f64>,
    /// `power_mw <= X` on the (predicted) array power.
    pub max_power_mw: Option<f64>,
    /// `latency <= X` milliseconds per inference.
    pub max_latency_ms: Option<f64>,
    /// Every precision cell in the palette must have `act_bits >= b` and
    /// `wt_bits >= b` (a syntactic accuracy floor: the optimizer may not
    /// quantize below it).
    pub min_bits: Option<u32>,
    /// `estimated accuracy >= X` on the genome's accuracy estimate — the
    /// *model-based* accuracy floor.  Evaluated per genome by the engine
    /// (see [`Constraints::accuracy_violation`]), not off the `DsePoint`.
    pub min_accuracy: Option<f64>,
}

impl Constraints {
    pub fn is_empty(&self) -> bool {
        self.max_area_mm2.is_none()
            && self.max_power_mw.is_none()
            && self.max_latency_ms.is_none()
            && self.min_bits.is_none()
            && self.min_accuracy.is_none()
    }

    /// Bounds must be positive; errors name the field.
    pub fn validate(&self) -> Result<(), QappaError> {
        for (field, v) in [
            ("max_area_mm2", self.max_area_mm2),
            ("max_power_mw", self.max_power_mw),
            ("max_latency_ms", self.max_latency_ms),
        ] {
            if let Some(x) = v {
                if !(x > 0.0) {
                    return Err(QappaError::Config(format!(
                        "optimize: constraint {field} must be a positive number (got {x})"
                    )));
                }
            }
        }
        if let Some(x) = self.min_accuracy {
            if !(x > 0.0 && x <= 1.0) {
                return Err(QappaError::Config(format!(
                    "optimize: constraint min_accuracy must be in (0, 1] (got {x})"
                )));
            }
        }
        Ok(())
    }

    /// Total normalized violation of the evaluated bounds: 0 when every
    /// constraint holds, otherwise the sum of relative excesses — the
    /// constraint-domination key NSGA-II ranks infeasible points by.
    /// (`min_bits` never contributes: it is enforced on the palette.)
    pub fn violation(&self, p: &DsePoint) -> f64 {
        let mut v = 0.0;
        if let Some(x) = self.max_area_mm2 {
            v += ((p.ppa.area_mm2 - x) / x).max(0.0);
        }
        if let Some(x) = self.max_power_mw {
            v += ((p.ppa.power_mw - x) / x).max(0.0);
        }
        if let Some(x) = self.max_latency_ms {
            let lat_ms = 1e3 / p.throughput.max(1e-300);
            v += ((lat_ms - x) / x).max(0.0);
        }
        v
    }

    /// Normalized `min_accuracy` shortfall for one genome's accuracy
    /// estimate, on the same relative scale as [`Constraints::violation`].
    /// A missing estimate under an active floor counts as a full
    /// violation, so an unwired accuracy model can never pass the gate.
    pub fn accuracy_violation(&self, accuracy: Option<f64>) -> f64 {
        match self.min_accuracy {
            None => 0.0,
            Some(floor) => {
                let acc = accuracy.unwrap_or(0.0);
                ((floor - acc) / floor).max(0.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, PeType};
    use crate::synth::oracle::Ppa;

    fn point(power_mw: f64, area_mm2: f64, throughput: f64, energy_mj: f64) -> DsePoint {
        DsePoint {
            cfg: AcceleratorConfig::default_with(PeType::Int16),
            ppa: Ppa { power_mw, fmax_mhz: 800.0, area_mm2 },
            throughput,
            perf_per_area: throughput / area_mm2,
            energy_mj,
            utilization: 0.8,
        }
    }

    #[test]
    fn objective_values_canonicalize_to_minimize() {
        let p = point(250.0, 2.0, 100.0, 5.0);
        assert!((Objective::Latency.value(&p) - 0.01).abs() < 1e-12);
        assert_eq!(Objective::Energy.value(&p), 5.0);
        assert_eq!(Objective::Area.value(&p), 2.0);
        assert_eq!(Objective::Power.value(&p), 250.0);
        assert!((Objective::PerfPerArea.value(&p) - 2.0 / 100.0).abs() < 1e-12);
        // perf/energy inverse == EDP: energy x latency
        assert!((Objective::PerfPerEnergy.value(&p) - 0.05).abs() < 1e-12);
        assert_eq!(Objective::PerfPerEnergy.value(&p), Objective::Edp.value(&p));
        // better points score lower on every point-valued objective
        let better = point(200.0, 1.5, 150.0, 4.0);
        for o in ALL_OBJECTIVES {
            if o.needs_accuracy() {
                continue;
            }
            assert!(o.value(&better) < o.value(&p), "{}", o.label());
        }
    }

    #[test]
    fn accuracy_objective_minimizes_one_minus_accuracy() {
        let p = point(250.0, 2.0, 100.0, 5.0);
        let o = Objective::Accuracy;
        assert!(o.needs_accuracy());
        assert!((o.value_with(&p, Some(0.9)) - 0.1).abs() < 1e-12);
        assert!(o.value_with(&p, Some(0.95)) < o.value_with(&p, Some(0.9)));
        // a missing estimate scores as the worst case, never the best
        assert_eq!(o.value_with(&p, None), 1.0);
        // point-valued objectives ignore the estimate
        assert_eq!(Objective::Energy.value_with(&p, Some(0.5)), 5.0);
    }

    #[test]
    fn objective_parse_roundtrip_and_aliases() {
        for o in ALL_OBJECTIVES {
            assert_eq!(Objective::parse(o.label()).unwrap(), o);
            assert_eq!(Objective::parse(&o.label().to_ascii_uppercase()).unwrap(), o);
        }
        assert_eq!(Objective::parse("lat").unwrap(), Objective::Latency);
        assert_eq!(Objective::parse("perf_per_area").unwrap(), Objective::PerfPerArea);
        let e = Objective::parse("bogus").unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.to_string().contains("perf/area"), "{e}");
    }

    #[test]
    fn resolve_objectives_defaults_and_rejects() {
        assert_eq!(
            resolve_objectives(&[]).unwrap(),
            vec![Objective::PerfPerArea, Objective::Energy]
        );
        let two = resolve_objectives(&["lat".into(), "energy".into()]).unwrap();
        assert_eq!(two, vec![Objective::Latency, Objective::Energy]);
        let three =
            resolve_objectives(&["lat".into(), "energy".into(), "accuracy".into()]).unwrap();
        assert_eq!(three, vec![Objective::Latency, Objective::Energy, Objective::Accuracy]);
        let e = resolve_objectives(&["lat".into()]).unwrap_err();
        assert!(e.to_string().contains("two or three"), "{e}");
        let four: Vec<String> =
            ["lat", "energy", "area", "power"].map(String::from).to_vec();
        assert!(resolve_objectives(&four).unwrap_err().to_string().contains("two or three"));
        let e = resolve_objectives(&["energy".into(), "energy".into()]).unwrap_err();
        assert!(e.to_string().contains("distinct"), "{e}");
        // value-aliased pair: perf/energy and edp minimize the same scalar
        let e = resolve_objectives(&["perf/energy".into(), "edp".into()]).unwrap_err();
        assert!(e.to_string().contains("distinct"), "{e}");
        // ...including buried inside a triple
        let e = resolve_objectives(&["edp".into(), "area".into(), "perf/energy".into()])
            .unwrap_err();
        assert!(e.to_string().contains("distinct"), "{e}");
        assert!(resolve_objectives(&["lat".into(), "nope".into()]).is_err());
    }

    #[test]
    fn constraint_violation_is_zero_when_satisfied_and_scales_with_excess() {
        let c = Constraints {
            max_area_mm2: Some(2.5),
            max_power_mw: Some(300.0),
            max_latency_ms: Some(20.0),
            min_bits: Some(4),
            min_accuracy: None,
        };
        c.validate().unwrap();
        // satisfied on every axis
        assert_eq!(c.violation(&point(250.0, 2.0, 100.0, 5.0)), 0.0);
        // area 25% over budget
        let v = c.violation(&point(250.0, 3.125, 100.0, 5.0));
        assert!((v - 0.25).abs() < 1e-12, "{v}");
        // violations on several axes sum
        let v2 = c.violation(&point(600.0, 5.0, 10.0, 5.0));
        assert!(v2 > 1.0, "{v2}");
        // no constraints: everything feasible
        assert_eq!(Constraints::default().violation(&point(1e9, 1e9, 1e-9, 1e9)), 0.0);
        assert!(Constraints::default().is_empty());
        assert!(!c.is_empty());
        // non-positive bounds are config errors naming the field
        let bad = Constraints { max_area_mm2: Some(0.0), ..Default::default() };
        let e = bad.validate().unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.to_string().contains("max_area_mm2"), "{e}");
    }

    #[test]
    fn min_accuracy_floor_validates_and_scores_shortfall() {
        let c = Constraints { min_accuracy: Some(0.9), ..Default::default() };
        c.validate().unwrap();
        assert!(!c.is_empty());
        assert_eq!(c.accuracy_violation(Some(0.95)), 0.0);
        assert_eq!(c.accuracy_violation(Some(0.9)), 0.0);
        let v = c.accuracy_violation(Some(0.45));
        assert!((v - 0.5).abs() < 1e-12, "{v}");
        // a missing estimate under an active floor is a full violation
        assert!((c.accuracy_violation(None) - 1.0).abs() < 1e-12);
        // no floor: nothing to violate
        assert_eq!(Constraints::default().accuracy_violation(None), 0.0);
        for bad in [0.0, -0.5, 1.5] {
            let c = Constraints { min_accuracy: Some(bad), ..Default::default() };
            let e = c.validate().unwrap_err();
            assert!(e.to_string().contains("min_accuracy"), "{e}");
        }
    }
}
