//! Guided multi-objective optimization — constraint-driven search over
//! hardware × per-layer precision.
//!
//! The exhaustive DSE ([`crate::coordinator`]) widens with every axis: the
//! precision grid alone multiplies the hardware grid into millions of
//! cells, and the per-layer assignment space (`|palette|^|layers|`) cannot
//! be enumerated at all.  This subsystem searches that joint space under a
//! fixed evaluation budget instead:
//!
//! * [`objective`] — named objectives (latency, energy, area, power,
//!   perf/area, perf/energy, EDP, accuracy), canonicalized to minimize,
//!   plus hard constraints (`area_mm2 <= X`, `power_mw <= X`,
//!   `latency <= X ms`, `min bits >= b`, `accuracy >= a`) evaluated off
//!   the existing dataflow cost struct and the
//!   [`crate::accuracy::AccuracyModel`] estimate;
//! * [`genome`] — the (hardware axes × model knobs × per-layer precision)
//!   encoding and its seeded variation operators;
//! * [`engine`] — NSGA-II-style evolutionary search with random-sampling
//!   and hill-climb baselines behind a common [`Strategy`] trait, batching
//!   every evaluation through the streaming sweep's predict → dataflow
//!   pipeline and folding feasible points into one
//!   [`crate::coordinator::pareto::IncrementalFrontier`] archive whose
//!   [`hypervolume`](crate::coordinator::pareto::hypervolume) is the
//!   convergence currency.
//!
//! Sessions expose the subsystem as [`crate::api::Qappa::optimize`]
//! (`qappa optimize` on the CLI, the `optimize` op over `qappa serve`);
//! models come from the session's `ModelStore`, so guided search shares
//! training passes with every other query.  Transformer workloads are
//! optimized for one concrete inference phase (`--phase prefill|decode`
//! with `--ctx`): LLM decode is the bandwidth-bound KV-cache-dominated
//! regime, so a decode-phase search lands on very different frontiers
//! than a prefill (compute-bound) one.  Grammar, strategy comparison
//! and budget guidance: `docs/OPTIMIZER.md`; the accuracy objective's
//! noise model and sensitivity-table schema: `docs/ACCURACY.md`.

pub mod engine;
pub mod genome;
pub mod objective;

pub use engine::{
    run_optimize, run_optimize_cancellable, CancelToken, EvalRecord, Evaluator,
    FrontierPoint, GenStat, HillClimb, Nsga2, OptOptions, OptProblem, OptResult,
    RandomSearch, Strategy, StrategyKind,
};
pub use genome::{Genome, ModelKnobs, SearchSpace};
pub use objective::{resolve_objectives, Constraints, Objective, ALL_OBJECTIVES};
