//! Genome encoding of one (hardware config, per-layer precision) candidate
//! and the variation operators the search engine breeds with.
//!
//! A [`Genome`] is pure index space: seven digits selecting one value per
//! [`DesignSpace`] hardware axis, plus a precision vector of indices into a
//! validated palette of [`PeType`] cells — one index per layer when
//! per-layer assignment is on, a single index for a uniform design.
//! [`SearchSpace::decode`] lowers a genome to the concrete
//! [`AcceleratorConfig`] + override-carrying layer list that the existing
//! predict → dataflow pipeline evaluates.
//!
//! In per-layer mode the array is provisioned for the **widest** assigned
//! spec (element-wise max over operand/accumulator widths, the most
//! expensive datapath kind present): the predicted area/power are those of
//! hardware that can actually run every layer, so a genome cannot game an
//! area constraint by declaring a narrow array and running wide layers.
//!
//! With model-side knobs attached ([`SearchSpace::with_model_knobs`]) the
//! genome additionally carries two *model* genes — indices into a
//! channel-width multiplier axis and a depth multiplier axis — and decode
//! swaps in the matching pre-built scaled variant of the workload
//! (QUIDAM-style joint hardware/model exploration).  Multipliers live in
//! (0, 1] so every variant's layer names are a subset of the full model's
//! and measured sensitivity tables stay valid for every variant.

use crate::api::error::QappaError;
use crate::config::{AcceleratorConfig, MacKind, PeType, QuantSpec};
use crate::coordinator::space::DesignSpace;
use crate::dataflow::Layer;
use crate::util::prng::Rng;

/// Number of hardware axes in a genome (mirrors the [`DesignSpace`] axes).
pub const HW_GENES: usize = 7;

/// One candidate design: hardware axis digits + model knobs + precision
/// assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Genome {
    /// Indices into the design-space axes, in order: rows, cols, glb_kb,
    /// spad_ifmap_b, spad_filter_b, spad_psum_b, bandwidth_gbps.
    pub hw: [usize; HW_GENES],
    /// Model-knob indices: empty without model knobs, else
    /// `[width_index, depth_index]` into the multiplier axes.
    pub model: Vec<usize>,
    /// Palette indices: length 1 (uniform precision) or one per layer.
    pub prec: Vec<usize>,
}

impl Genome {
    /// Stable dedup/cache key.
    pub fn key(&self) -> Vec<u32> {
        let mut k = Vec::with_capacity(HW_GENES + self.model.len() + self.prec.len());
        k.extend(self.hw.iter().map(|&i| i as u32));
        k.extend(self.model.iter().map(|&i| i as u32));
        k.extend(self.prec.iter().map(|&i| i as u32));
        k
    }
}

/// Model-side knob axes: channel-width and depth multipliers plus the
/// pre-built scaled workload variant for every (width, depth) cell.
/// Variants are materialized once at construction so decode stays an
/// index lookup on the search hot path.
#[derive(Debug, Clone)]
pub struct ModelKnobs {
    /// Channel-width multipliers, each in (0, 1].
    pub width: Vec<f64>,
    /// Depth multipliers, each in (0, 1].
    pub depth: Vec<f64>,
    /// Scaled variants, width-major: `variants[wi * depth.len() + di]`.
    variants: Vec<Vec<Layer>>,
}

impl ModelKnobs {
    /// The variant for one (width index, depth index) cell.
    pub fn variant(&self, wi: usize, di: usize) -> &[Layer] {
        &self.variants[wi * self.depth.len() + di]
    }
}

/// The decoded search domain: hardware axes x model knobs x precision
/// palette x layers.
pub struct SearchSpace<'a> {
    space: &'a DesignSpace,
    /// Validated precision cells the genome indexes into.
    pub palette: Vec<PeType>,
    /// The full-size workload being optimized for (the widest variant when
    /// model knobs are attached).
    pub layers: &'a [Layer],
    /// One precision gene per layer (mixed precision) vs a single gene.
    pub per_layer: bool,
    /// Model-side knob axes; `None` = hardware/precision search only.
    pub model: Option<ModelKnobs>,
}

impl<'a> SearchSpace<'a> {
    /// Build a search space, validating the hardware axes (structured
    /// errors for empty axes — see [`DesignSpace::validate`]), the palette
    /// and the workload.
    pub fn new(
        space: &'a DesignSpace,
        palette: Vec<PeType>,
        layers: &'a [Layer],
        per_layer: bool,
    ) -> Result<SearchSpace<'a>, QappaError> {
        space.validate()?;
        // The optimizer owns the precision axis through the palette; a
        // quants-extended space (the exhaustive sweep's construction)
        // would be silently ignored by decode(), so reject it loudly.
        if !space.quants.is_empty() {
            return Err(QappaError::Config(
                "optimize: the design space must not carry a quants axis — \
                 precision is searched through the palette"
                    .into(),
            ));
        }
        if palette.is_empty() {
            return Err(QappaError::Config("optimize: empty precision palette".into()));
        }
        for ty in &palette {
            ty.spec()
                .validate()
                .map_err(|e| e.context(format!("optimize: palette cell '{}'", ty.label())))?;
        }
        if layers.is_empty() {
            return Err(QappaError::Workload("optimize: workload has no layers".into()));
        }
        Ok(SearchSpace { space, palette, layers, per_layer, model: None })
    }

    /// Attach model-side knobs: multiplier axes plus one pre-built scaled
    /// variant per (width, depth) cell, width-major.  Multipliers must lie
    /// in (0, 1] and every variant must be a non-empty sub-model of the
    /// base workload (no more layers than the base, names drawn from the
    /// base) so precision genes and sensitivity tables keyed to the base
    /// stay valid for every variant.
    pub fn with_model_knobs(
        mut self,
        width: Vec<f64>,
        depth: Vec<f64>,
        variants: Vec<Vec<Layer>>,
    ) -> Result<SearchSpace<'a>, QappaError> {
        let cfg_err = |m: String| Err(QappaError::Config(format!("optimize: {m}")));
        if width.is_empty() || depth.is_empty() {
            return cfg_err("model knob axes must not be empty".into());
        }
        for (axis, vals) in [("width_mults", &width), ("depth_mults", &depth)] {
            for &v in vals {
                if !(v.is_finite() && v > 0.0 && v <= 1.0) {
                    return cfg_err(format!("{axis} values must lie in (0, 1], got {v}"));
                }
            }
        }
        if variants.len() != width.len() * depth.len() {
            return cfg_err(format!(
                "expected {} scaled variants ({} widths x {} depths), got {}",
                width.len() * depth.len(),
                width.len(),
                depth.len(),
                variants.len()
            ));
        }
        for (i, v) in variants.iter().enumerate() {
            if v.is_empty() {
                return cfg_err(format!("scaled variant {i} has no layers"));
            }
            if v.len() > self.layers.len() {
                return cfg_err(format!(
                    "scaled variant {i} has {} layers, more than the base workload's {} — \
                     multipliers must shrink the model",
                    v.len(),
                    self.layers.len()
                ));
            }
            for l in v {
                if !self.layers.iter().any(|b| b.name == l.name) {
                    return cfg_err(format!(
                        "scaled variant {i} layer '{}' is not a base workload layer",
                        l.name
                    ));
                }
            }
        }
        self.model = Some(ModelKnobs { width, depth, variants });
        Ok(self)
    }

    /// Lengths of the seven hardware axes, genome order.
    pub fn axis_lens(&self) -> [usize; HW_GENES] {
        [
            self.space.rows.len(),
            self.space.cols.len(),
            self.space.glb_kb.len(),
            self.space.spad_ifmap_b.len(),
            self.space.spad_filter_b.len(),
            self.space.spad_psum_b.len(),
            self.space.bandwidth_gbps.len(),
        ]
    }

    /// Precision gene count: one per layer in per-layer mode (when the
    /// palette offers a choice), a single gene otherwise.
    pub fn prec_len(&self) -> usize {
        if self.per_layer && self.palette.len() > 1 {
            self.layers.len()
        } else {
            1
        }
    }

    /// Model gene count: `[width, depth]` when knobs are attached.
    pub fn model_len(&self) -> usize {
        if self.model.is_some() {
            2
        } else {
            0
        }
    }

    /// Total genes (mutation-rate denominator).
    pub fn genes(&self) -> usize {
        HW_GENES + self.model_len() + self.prec_len()
    }

    /// Size of the uniform-precision grid this space embeds (hardware grid
    /// x palette) — the exhaustive-sweep baseline the optimizer is
    /// measured against.  The full per-layer space is `|hw| x
    /// |palette|^|layers|` and is never materialized.
    pub fn uniform_grid_len(&self) -> usize {
        self.space.len().max(1) * self.palette.len()
    }

    /// Uniformly random genome.  Model genes (when knobs are attached) are
    /// drawn between the hardware digits and the precision vector, so the
    /// knob-free stream is unchanged.
    pub fn random(&self, rng: &mut Rng) -> Genome {
        let lens = self.axis_lens();
        let mut hw = [0usize; HW_GENES];
        for (g, &len) in hw.iter_mut().zip(lens.iter()) {
            *g = rng.below(len);
        }
        let model = match &self.model {
            None => Vec::new(),
            Some(mk) => vec![rng.below(mk.width.len()), rng.below(mk.depth.len())],
        };
        let prec = (0..self.prec_len()).map(|_| rng.below(self.palette.len())).collect();
        Genome { hw, model, prec }
    }

    /// Deterministic seeds covering the corners of the embedded uniform
    /// grid: for each palette cell, the all-minimum, all-maximum and
    /// mid-index hardware points at uniform precision.  Seeding the
    /// population with these anchors the search at the extremes each
    /// objective is pulled toward.
    pub fn corner_seeds(&self) -> Vec<Genome> {
        let lens = self.axis_lens();
        let prec_len = self.prec_len();
        // With model knobs, anchor corner seeds at the *fullest* model
        // (argmax multiplier on each axis): the accuracy ceiling every
        // slimmer variant is traded off against.
        let model = match &self.model {
            None => Vec::new(),
            Some(mk) => vec![argmax(&mk.width), argmax(&mk.depth)],
        };
        let mut out = Vec::with_capacity(3 * self.palette.len());
        for cell in 0..self.palette.len() {
            for pick in 0..3usize {
                let mut hw = [0usize; HW_GENES];
                for (g, &len) in hw.iter_mut().zip(lens.iter()) {
                    *g = match pick {
                        0 => 0,
                        1 => len - 1,
                        _ => len / 2,
                    };
                }
                out.push(Genome { hw, model: model.clone(), prec: vec![cell; prec_len] });
            }
        }
        out
    }

    /// The widest spec the genome assigns anywhere — the precision the
    /// array is provisioned (and therefore priced) at.
    fn array_type(&self, prec: &[usize]) -> PeType {
        if prec.len() == 1 {
            return self.palette[prec[0]];
        }
        let mut act = 0u32;
        let mut wt = 0u32;
        let mut psum = 0u32;
        let mut mac = MacKind::IntExact;
        let mut mac_code = f64::NEG_INFINITY;
        let mut light_terms = 0u32;
        for &i in prec {
            let q = self.palette[i].spec();
            act = act.max(q.act_bits);
            wt = wt.max(q.wt_bits);
            psum = psum.max(q.psum_bits);
            if let MacKind::Lightweight(n) = q.mac {
                light_terms = light_terms.max(n);
            }
            if q.mac.code() > mac_code {
                mac_code = q.mac.code();
                mac = q.mac;
            }
        }
        // The priciest lightweight variant present, if lightweight won.
        if let MacKind::Lightweight(_) = mac {
            mac = MacKind::Lightweight(light_terms.max(1));
        }
        PeType::from_spec(QuantSpec { act_bits: act, wt_bits: wt, psum_bits: psum, mac })
    }

    /// Lower a genome to the concrete design the pipeline evaluates: the
    /// accelerator config (array at the widest assigned spec) and the
    /// layer list with per-layer precision overrides installed.  Any
    /// precision overrides the source workload carried are replaced by the
    /// genome's assignment (the optimizer owns the precision axis).
    ///
    /// With model knobs attached the genome's model genes pick the scaled
    /// variant, and only the *active* prefix of the precision vector (one
    /// gene per variant layer) participates: silent tail genes on a
    /// depth-reduced variant can neither widen the priced array nor leak
    /// overrides.
    pub fn decode(&self, g: &Genome) -> (AcceleratorConfig, Vec<Layer>) {
        let base: &[Layer] = match (&self.model, g.model.as_slice()) {
            (Some(mk), &[wi, di]) => mk.variant(wi, di),
            _ => self.layers,
        };
        let active = &g.prec[..g.prec.len().min(base.len().max(1))];
        let array = self.array_type(active);
        let cfg = AcceleratorConfig {
            pe_type: array,
            pe_rows: self.space.rows[g.hw[0]],
            pe_cols: self.space.cols[g.hw[1]],
            glb_kb: self.space.glb_kb[g.hw[2]],
            spad_ifmap_b: self.space.spad_ifmap_b[g.hw[3]],
            spad_filter_b: self.space.spad_filter_b[g.hw[4]],
            spad_psum_b: self.space.spad_psum_b[g.hw[5]],
            bandwidth_gbps: self.space.bandwidth_gbps[g.hw[6]],
        };
        let array_spec = cfg.quant();
        let mut layers = base.to_vec();
        if active.len() == 1 {
            for l in layers.iter_mut() {
                l.quant = None;
            }
        } else {
            for (l, &i) in layers.iter_mut().zip(active) {
                let spec = self.palette[i].spec();
                l.quant = if spec == array_spec { None } else { Some(spec) };
            }
        }
        (cfg, layers)
    }

    /// The (width, depth) multipliers a genome selects; `(1.0, 1.0)` when
    /// no model knobs are attached.
    pub fn model_mults(&self, g: &Genome) -> (f64, f64) {
        match (&self.model, g.model.as_slice()) {
            (Some(mk), &[wi, di]) => (mk.width[wi], mk.depth[di]),
            _ => (1.0, 1.0),
        }
    }

    /// Per-layer precision labels of a genome (report surface): one label
    /// per layer in per-layer mode, a single label for a uniform design.
    pub fn precision_labels(&self, g: &Genome) -> Vec<String> {
        g.prec.iter().map(|&i| self.palette[i].label()).collect()
    }

    /// Uniform crossover: each gene swaps between the children with
    /// probability 1/2.
    pub fn crossover(&self, a: &Genome, b: &Genome, rng: &mut Rng) -> (Genome, Genome) {
        let mut c1 = a.clone();
        let mut c2 = b.clone();
        for i in 0..HW_GENES {
            if rng.f64() < 0.5 {
                std::mem::swap(&mut c1.hw[i], &mut c2.hw[i]);
            }
        }
        let m = c1.model.len().min(c2.model.len());
        for i in 0..m {
            if rng.f64() < 0.5 {
                std::mem::swap(&mut c1.model[i], &mut c2.model[i]);
            }
        }
        let n = c1.prec.len().min(c2.prec.len());
        for i in 0..n {
            if rng.f64() < 0.5 {
                std::mem::swap(&mut c1.prec[i], &mut c2.prec[i]);
            }
        }
        (c1, c2)
    }

    /// Mutate in place: each gene flips with probability `1/genes`; half
    /// of the flips take a ±1 step along the axis (local refinement on the
    /// smooth PPA landscape), half resample uniformly (escape hatch).  If
    /// the pass changed nothing, one random gene is forced so a child is
    /// never a clone of its parent.
    pub fn mutate(&self, g: &mut Genome, rng: &mut Rng) {
        let lens = self.axis_lens();
        let pm = 1.0 / self.genes() as f64;
        let mut changed = false;
        for i in 0..HW_GENES {
            if rng.f64() < pm {
                changed |= self.mutate_gene(&mut g.hw[i], lens[i], rng);
            }
        }
        // Model-knob axis lengths, positional: [width, depth].  Knob-free
        // genomes have no model genes, so both loops below are no-ops and
        // the pre-knob random stream is preserved byte-for-byte.
        let mlens: [usize; 2] = match &self.model {
            Some(mk) => [mk.width.len(), mk.depth.len()],
            None => [1, 1],
        };
        for (i, gene) in g.model.iter_mut().enumerate() {
            if rng.f64() < pm {
                changed |= self.mutate_gene(gene, mlens[i.min(1)], rng);
            }
        }
        let pal = self.palette.len();
        for gene in g.prec.iter_mut() {
            if rng.f64() < pm {
                changed |= self.mutate_gene(gene, pal, rng);
            }
        }
        if !changed {
            // Force one flip so a child is never a parent clone — unless
            // every gene sits on a length-1 axis (a fully degenerate
            // domain), in which case there is nothing to move.
            let nmodel = g.model.len();
            let movable = lens.iter().any(|&l| l > 1)
                || (0..nmodel).any(|i| mlens[i.min(1)] > 1)
                || (pal > 1 && !g.prec.is_empty());
            while movable && !changed {
                let pick = rng.below(HW_GENES + nmodel + g.prec.len());
                changed = if pick < HW_GENES {
                    self.mutate_gene(&mut g.hw[pick], lens[pick], rng)
                } else if pick < HW_GENES + nmodel {
                    let mi = pick - HW_GENES;
                    self.mutate_gene(&mut g.model[mi], mlens[mi.min(1)], rng)
                } else {
                    self.mutate_gene(&mut g.prec[pick - HW_GENES - nmodel], pal, rng)
                };
            }
        }
    }

    /// One gene flip; returns whether the value actually moved.
    fn mutate_gene(&self, gene: &mut usize, len: usize, rng: &mut Rng) -> bool {
        mutate_index(gene, len, rng)
    }
}

/// Index of the largest value (first wins ties); callers pass validated
/// non-empty axes.
fn argmax(vals: &[f64]) -> usize {
    let mut best = 0;
    for (i, &v) in vals.iter().enumerate() {
        if v > vals[best] {
            best = i;
        }
    }
    best
}

/// One index flip on an axis of `len` values; returns whether it moved.
fn mutate_index(gene: &mut usize, len: usize, rng: &mut Rng) -> bool {
    if len <= 1 {
        return false;
    }
    let old = *gene;
    if rng.f64() < 0.5 {
        // ±1 step, clamped to the axis
        *gene = if rng.f64() < 0.5 {
            gene.saturating_sub(1)
        } else {
            (*gene + 1).min(len - 1)
        };
    } else {
        *gene = rng.below(len);
    }
    *gene != old
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ALL_PE_TYPES;

    fn layers() -> Vec<Layer> {
        vec![
            Layer::conv("c1", 3, 16, 32, 32, 3, 1, 1),
            Layer::dw("dw", 16, 16, 3, 1, 1),
            Layer::fc("fc", 256, 10),
        ]
    }

    fn space() -> DesignSpace {
        DesignSpace::tiny()
    }

    #[test]
    fn random_genomes_decode_to_valid_designs() {
        let s = space();
        let ls = layers();
        let search = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let g = search.random(&mut rng);
            assert_eq!(g.prec.len(), ls.len());
            let (cfg, decoded) = search.decode(&g);
            cfg.validate().unwrap();
            assert_eq!(decoded.len(), ls.len());
            // every override stays within the palette's specs
            for l in &decoded {
                if let Some(q) = l.quant {
                    assert!(ALL_PE_TYPES.iter().any(|t| t.spec() == q));
                    assert_ne!(q, cfg.quant(), "override equal to the array spec must be None");
                }
            }
        }
    }

    #[test]
    fn decode_is_deterministic_and_keyed() {
        let s = space();
        let ls = layers();
        let search = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        let mut rng = Rng::new(3);
        let g = search.random(&mut rng);
        let (c1, l1) = search.decode(&g);
        let (c2, l2) = search.decode(&g);
        assert_eq!(c1, c2);
        assert_eq!(l1, l2);
        assert_eq!(g.key(), g.clone().key());
        let h = search.random(&mut rng);
        if g != h {
            assert_ne!(g.key(), h.key());
        }
    }

    #[test]
    fn array_is_provisioned_for_the_widest_assigned_spec() {
        let s = space();
        let ls = layers();
        let palette = vec![
            PeType::from_spec(QuantSpec::int(4, 4)),
            PeType::Int16,
            PeType::LightPe1,
        ];
        let search = SearchSpace::new(&s, palette, &ls, true).unwrap();
        // all layers at INT4 -> array is the INT4 cell
        let g = Genome { hw: [0; HW_GENES], model: vec![], prec: vec![0, 0, 0] };
        let (cfg, _) = search.decode(&g);
        assert_eq!(cfg.quant(), QuantSpec::int(4, 4));
        // mixing INT4 with INT16 -> array widens to cover INT16
        let g = Genome { hw: [0; HW_GENES], model: vec![], prec: vec![0, 1, 0] };
        let (cfg, dec) = search.decode(&g);
        assert!(cfg.quant().act_bits >= 16 && cfg.quant().psum_bits >= 32);
        // the INT4 layers carry overrides, the INT16 layer matches the array
        assert!(dec[0].quant.is_some() && dec[2].quant.is_some());
        // mixing in a lightweight cell promotes the datapath kind
        let g = Genome { hw: [0; HW_GENES], model: vec![], prec: vec![0, 1, 2] };
        let (cfg, _) = search.decode(&g);
        assert!(cfg.quant().is_light());
        assert!(cfg.quant().act_bits >= 16);
        cfg.validate().unwrap();
    }

    #[test]
    fn uniform_mode_uses_one_gene_and_no_overrides() {
        let s = space();
        let ls = layers();
        let search = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, false).unwrap();
        assert_eq!(search.prec_len(), 1);
        let mut rng = Rng::new(5);
        let g = search.random(&mut rng);
        assert_eq!(g.prec.len(), 1);
        let (cfg, dec) = search.decode(&g);
        assert_eq!(cfg.pe_type, search.palette[g.prec[0]]);
        assert!(dec.iter().all(|l| l.quant.is_none()));
        // single-cell palettes degenerate to one gene even per-layer
        let one = SearchSpace::new(&s, vec![PeType::Int16], &ls, true).unwrap();
        assert_eq!(one.prec_len(), 1);
        assert_eq!(one.uniform_grid_len(), s.len());
    }

    #[test]
    fn variation_operators_stay_in_range_and_are_seeded() {
        let s = space();
        let ls = layers();
        let search = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        let lens = search.axis_lens();
        let mut rng = Rng::new(11);
        let a = search.random(&mut rng);
        let b = search.random(&mut rng);
        let (c1, c2) = search.crossover(&a, &b, &mut rng);
        for c in [&c1, &c2] {
            for (i, &g) in c.hw.iter().enumerate() {
                assert!(g < lens[i]);
            }
            for &p in &c.prec {
                assert!(p < search.palette.len());
            }
        }
        // crossover conserves the multiset of genes per position
        for i in 0..HW_GENES {
            let mut before = [a.hw[i], b.hw[i]];
            let mut after = [c1.hw[i], c2.hw[i]];
            before.sort_unstable();
            after.sort_unstable();
            assert_eq!(before, after);
        }
        // mutation always changes something and stays in range
        for seed in 0..50u64 {
            let mut rng = Rng::new(seed);
            let mut g = search.random(&mut rng);
            let orig = g.clone();
            search.mutate(&mut g, &mut rng);
            assert_ne!(g, orig, "seed {seed}: mutation must move the genome");
            for (i, &d) in g.hw.iter().enumerate() {
                assert!(d < lens[i]);
            }
            for &p in &g.prec {
                assert!(p < search.palette.len());
            }
        }
        // same seed, same stream
        let mut r1 = Rng::new(99);
        let mut r2 = Rng::new(99);
        assert_eq!(search.random(&mut r1), search.random(&mut r2));
    }

    #[test]
    fn corner_seeds_cover_extremes_per_cell() {
        let s = space();
        let ls = layers();
        let search = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        let seeds = search.corner_seeds();
        assert_eq!(seeds.len(), 3 * search.palette.len());
        let lens = search.axis_lens();
        for g in &seeds {
            assert!(g.prec.iter().all(|&p| p == g.prec[0]), "seeds are uniform-precision");
            let (cfg, _) = search.decode(g);
            cfg.validate().unwrap();
            for (i, &d) in g.hw.iter().enumerate() {
                assert!(d < lens[i]);
            }
        }
        // the all-min and all-max corners are present
        assert!(seeds.iter().any(|g| g.hw.iter().all(|&d| d == 0)));
        assert!(seeds
            .iter()
            .any(|g| g.hw.iter().zip(lens.iter()).all(|(&d, &l)| d == l - 1)));
    }

    /// Hand-built scaled variants of `layers()` on width [1.0, 0.5] x
    /// depth [1.0, 0.5], width-major: depth 0.5 drops the middle dw layer,
    /// width 0.5 halves channels.
    fn knob_axes() -> (Vec<f64>, Vec<f64>, Vec<Vec<Layer>>) {
        let full = layers();
        let shallow = vec![full[0].clone(), full[2].clone()];
        let slim = vec![
            Layer::conv("c1", 3, 8, 32, 32, 3, 1, 1),
            Layer::dw("dw", 8, 16, 3, 1, 1),
            Layer::fc("fc", 128, 10),
        ];
        let slim_shallow = vec![slim[0].clone(), slim[2].clone()];
        (vec![1.0, 0.5], vec![1.0, 0.5], vec![full, shallow, slim, slim_shallow])
    }

    #[test]
    fn with_model_knobs_rejects_bad_axes_and_variants() {
        let s = space();
        let ls = layers();
        let (w, d, vs) = knob_axes();
        let build = || SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        // empty axis
        let e = build().with_model_knobs(Vec::new(), d.clone(), vs.clone()).unwrap_err();
        assert!(e.to_string().contains("model knob axes"), "{e}");
        // out-of-range multipliers name the axis
        let e = build().with_model_knobs(vec![1.5, 0.5], d.clone(), vs.clone()).unwrap_err();
        assert!(e.to_string().contains("width_mults"), "{e}");
        let e = build().with_model_knobs(w.clone(), vec![1.0, 0.0], vs.clone()).unwrap_err();
        assert!(e.to_string().contains("depth_mults"), "{e}");
        // wrong variant count
        let e = build().with_model_knobs(w.clone(), d.clone(), vs[..3].to_vec()).unwrap_err();
        assert!(e.to_string().contains("4 scaled variants"), "{e}");
        // empty variant
        let mut bad = vs.clone();
        bad[1] = Vec::new();
        let e = build().with_model_knobs(w.clone(), d.clone(), bad).unwrap_err();
        assert!(e.to_string().contains("no layers"), "{e}");
        // a variant larger than the base model
        let mut bad = vs.clone();
        bad[1] = [ls.clone(), vec![ls[0].clone()]].concat();
        let e = build().with_model_knobs(w.clone(), d.clone(), bad).unwrap_err();
        assert!(e.to_string().contains("more than the base"), "{e}");
        // a variant layer whose name the base model doesn't have
        let mut bad = vs.clone();
        bad[3] = vec![Layer::fc("mystery", 64, 10)];
        let e = build().with_model_knobs(w, d, bad).unwrap_err();
        assert!(e.to_string().contains("mystery"), "{e}");
    }

    #[test]
    fn model_genes_select_the_variant_and_only_active_precisions_count() {
        let s = space();
        let ls = layers();
        let (w, d, vs) = knob_axes();
        let palette = vec![PeType::from_spec(QuantSpec::int(4, 4)), PeType::Int16];
        let search = SearchSpace::new(&s, palette, &ls, true)
            .unwrap()
            .with_model_knobs(w, d, vs)
            .unwrap();
        assert_eq!(search.model_len(), 2);
        assert_eq!(search.genes(), HW_GENES + 2 + ls.len());
        // full model
        let full = Genome { hw: [0; HW_GENES], model: vec![0, 0], prec: vec![0, 0, 0] };
        let (_, dec) = search.decode(&full);
        assert_eq!(dec.len(), 3);
        assert_eq!(search.model_mults(&full), (1.0, 1.0));
        // slim + shallow variant: channels halved, dw layer gone
        let tiny = Genome { hw: [0; HW_GENES], model: vec![1, 1], prec: vec![0, 0, 0] };
        let (_, dec) = search.decode(&tiny);
        assert_eq!(dec.len(), 2);
        assert_eq!(dec[0].name, "c1");
        assert_eq!(dec[0].k, 8);
        assert_eq!(dec[1].name, "fc");
        assert_eq!(search.model_mults(&tiny), (0.5, 0.5));
        // model genes participate in the dedup key
        assert_ne!(full.key(), tiny.key());
        // a tail gene past the variant's layer count cannot widen the array
        let tail = Genome { hw: [0; HW_GENES], model: vec![0, 1], prec: vec![0, 0, 1] };
        let (cfg, dec) = search.decode(&tail);
        assert_eq!(dec.len(), 2);
        assert_eq!(cfg.quant(), QuantSpec::int(4, 4));
    }

    #[test]
    fn knobbed_variation_stays_in_range_and_seeds_the_full_model() {
        let s = space();
        let ls = layers();
        let (w, d, vs) = knob_axes();
        let search = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, true)
            .unwrap()
            .with_model_knobs(w, d, vs)
            .unwrap();
        // corner seeds anchor at the fullest model (argmax multiplier)
        for g in search.corner_seeds() {
            assert_eq!(g.model, vec![0, 0]);
            search.decode(&g).0.validate().unwrap();
        }
        let mut rng = Rng::new(17);
        for _ in 0..100 {
            let mut g = search.random(&mut rng);
            assert_eq!(g.model.len(), 2);
            assert!(g.model[0] < 2 && g.model[1] < 2);
            search.mutate(&mut g, &mut rng);
            assert!(g.model[0] < 2 && g.model[1] < 2);
            search.decode(&g).0.validate().unwrap();
        }
        // crossover conserves the multiset of model genes per position
        let a = search.random(&mut rng);
        let b = search.random(&mut rng);
        let (c1, c2) = search.crossover(&a, &b, &mut rng);
        for i in 0..2 {
            let mut before = [a.model[i], b.model[i]];
            let mut after = [c1.model[i], c2.model[i]];
            before.sort_unstable();
            after.sort_unstable();
            assert_eq!(before, after);
        }
        // knob-free spaces still breed model-gene-free genomes
        let plain = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        assert!(plain.random(&mut rng).model.is_empty());
        assert_eq!(plain.model_mults(&plain.random(&mut rng)), (1.0, 1.0));
    }

    #[test]
    fn empty_inputs_are_structured_errors() {
        let s = space();
        let ls = layers();
        let e = SearchSpace::new(&s, Vec::new(), &ls, true).unwrap_err();
        assert_eq!(e.kind(), "config");
        let empty: Vec<Layer> = Vec::new();
        let e = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &empty, true).unwrap_err();
        assert_eq!(e.kind(), "workload");
        let mut bad = DesignSpace::tiny();
        bad.rows.clear();
        let e = SearchSpace::new(&bad, ALL_PE_TYPES.to_vec(), &ls, true).unwrap_err();
        assert!(e.to_string().contains("rows"), "{e}");
        // a quants-extended space is rejected, not silently ignored
        let quanted = DesignSpace::tiny().with_quants(ALL_PE_TYPES.to_vec());
        let e = SearchSpace::new(&quanted, ALL_PE_TYPES.to_vec(), &ls, true).unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.to_string().contains("quants"), "{e}");
    }
}
