//! Genome encoding of one (hardware config, per-layer precision) candidate
//! and the variation operators the search engine breeds with.
//!
//! A [`Genome`] is pure index space: seven digits selecting one value per
//! [`DesignSpace`] hardware axis, plus a precision vector of indices into a
//! validated palette of [`PeType`] cells — one index per layer when
//! per-layer assignment is on, a single index for a uniform design.
//! [`SearchSpace::decode`] lowers a genome to the concrete
//! [`AcceleratorConfig`] + override-carrying layer list that the existing
//! predict → dataflow pipeline evaluates.
//!
//! In per-layer mode the array is provisioned for the **widest** assigned
//! spec (element-wise max over operand/accumulator widths, the most
//! expensive datapath kind present): the predicted area/power are those of
//! hardware that can actually run every layer, so a genome cannot game an
//! area constraint by declaring a narrow array and running wide layers.

use crate::api::error::QappaError;
use crate::config::{AcceleratorConfig, MacKind, PeType, QuantSpec};
use crate::coordinator::space::DesignSpace;
use crate::dataflow::Layer;
use crate::util::prng::Rng;

/// Number of hardware axes in a genome (mirrors the [`DesignSpace`] axes).
pub const HW_GENES: usize = 7;

/// One candidate design: hardware axis digits + precision assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Genome {
    /// Indices into the design-space axes, in order: rows, cols, glb_kb,
    /// spad_ifmap_b, spad_filter_b, spad_psum_b, bandwidth_gbps.
    pub hw: [usize; HW_GENES],
    /// Palette indices: length 1 (uniform precision) or one per layer.
    pub prec: Vec<usize>,
}

impl Genome {
    /// Stable dedup/cache key.
    pub fn key(&self) -> Vec<u32> {
        let mut k = Vec::with_capacity(HW_GENES + self.prec.len());
        k.extend(self.hw.iter().map(|&i| i as u32));
        k.extend(self.prec.iter().map(|&i| i as u32));
        k
    }
}

/// The decoded search domain: hardware axes x precision palette x layers.
pub struct SearchSpace<'a> {
    space: &'a DesignSpace,
    /// Validated precision cells the genome indexes into.
    pub palette: Vec<PeType>,
    /// The workload being optimized for.
    pub layers: &'a [Layer],
    /// One precision gene per layer (mixed precision) vs a single gene.
    pub per_layer: bool,
}

impl<'a> SearchSpace<'a> {
    /// Build a search space, validating the hardware axes (structured
    /// errors for empty axes — see [`DesignSpace::validate`]), the palette
    /// and the workload.
    pub fn new(
        space: &'a DesignSpace,
        palette: Vec<PeType>,
        layers: &'a [Layer],
        per_layer: bool,
    ) -> Result<SearchSpace<'a>, QappaError> {
        space.validate()?;
        // The optimizer owns the precision axis through the palette; a
        // quants-extended space (the exhaustive sweep's construction)
        // would be silently ignored by decode(), so reject it loudly.
        if !space.quants.is_empty() {
            return Err(QappaError::Config(
                "optimize: the design space must not carry a quants axis — \
                 precision is searched through the palette"
                    .into(),
            ));
        }
        if palette.is_empty() {
            return Err(QappaError::Config("optimize: empty precision palette".into()));
        }
        for ty in &palette {
            ty.spec()
                .validate()
                .map_err(|e| e.context(format!("optimize: palette cell '{}'", ty.label())))?;
        }
        if layers.is_empty() {
            return Err(QappaError::Workload("optimize: workload has no layers".into()));
        }
        Ok(SearchSpace { space, palette, layers, per_layer })
    }

    /// Lengths of the seven hardware axes, genome order.
    pub fn axis_lens(&self) -> [usize; HW_GENES] {
        [
            self.space.rows.len(),
            self.space.cols.len(),
            self.space.glb_kb.len(),
            self.space.spad_ifmap_b.len(),
            self.space.spad_filter_b.len(),
            self.space.spad_psum_b.len(),
            self.space.bandwidth_gbps.len(),
        ]
    }

    /// Precision gene count: one per layer in per-layer mode (when the
    /// palette offers a choice), a single gene otherwise.
    pub fn prec_len(&self) -> usize {
        if self.per_layer && self.palette.len() > 1 {
            self.layers.len()
        } else {
            1
        }
    }

    /// Total genes (mutation-rate denominator).
    pub fn genes(&self) -> usize {
        HW_GENES + self.prec_len()
    }

    /// Size of the uniform-precision grid this space embeds (hardware grid
    /// x palette) — the exhaustive-sweep baseline the optimizer is
    /// measured against.  The full per-layer space is `|hw| x
    /// |palette|^|layers|` and is never materialized.
    pub fn uniform_grid_len(&self) -> usize {
        self.space.len().max(1) * self.palette.len()
    }

    /// Uniformly random genome.
    pub fn random(&self, rng: &mut Rng) -> Genome {
        let lens = self.axis_lens();
        let mut hw = [0usize; HW_GENES];
        for (g, &len) in hw.iter_mut().zip(lens.iter()) {
            *g = rng.below(len);
        }
        let prec = (0..self.prec_len()).map(|_| rng.below(self.palette.len())).collect();
        Genome { hw, prec }
    }

    /// Deterministic seeds covering the corners of the embedded uniform
    /// grid: for each palette cell, the all-minimum, all-maximum and
    /// mid-index hardware points at uniform precision.  Seeding the
    /// population with these anchors the search at the extremes each
    /// objective is pulled toward.
    pub fn corner_seeds(&self) -> Vec<Genome> {
        let lens = self.axis_lens();
        let prec_len = self.prec_len();
        let mut out = Vec::with_capacity(3 * self.palette.len());
        for cell in 0..self.palette.len() {
            for pick in 0..3usize {
                let mut hw = [0usize; HW_GENES];
                for (g, &len) in hw.iter_mut().zip(lens.iter()) {
                    *g = match pick {
                        0 => 0,
                        1 => len - 1,
                        _ => len / 2,
                    };
                }
                out.push(Genome { hw, prec: vec![cell; prec_len] });
            }
        }
        out
    }

    /// The widest spec the genome assigns anywhere — the precision the
    /// array is provisioned (and therefore priced) at.
    fn array_type(&self, prec: &[usize]) -> PeType {
        if prec.len() == 1 {
            return self.palette[prec[0]];
        }
        let mut act = 0u32;
        let mut wt = 0u32;
        let mut psum = 0u32;
        let mut mac = MacKind::IntExact;
        let mut mac_code = f64::NEG_INFINITY;
        let mut light_terms = 0u32;
        for &i in prec {
            let q = self.palette[i].spec();
            act = act.max(q.act_bits);
            wt = wt.max(q.wt_bits);
            psum = psum.max(q.psum_bits);
            if let MacKind::Lightweight(n) = q.mac {
                light_terms = light_terms.max(n);
            }
            if q.mac.code() > mac_code {
                mac_code = q.mac.code();
                mac = q.mac;
            }
        }
        // The priciest lightweight variant present, if lightweight won.
        if let MacKind::Lightweight(_) = mac {
            mac = MacKind::Lightweight(light_terms.max(1));
        }
        PeType::from_spec(QuantSpec { act_bits: act, wt_bits: wt, psum_bits: psum, mac })
    }

    /// Lower a genome to the concrete design the pipeline evaluates: the
    /// accelerator config (array at the widest assigned spec) and the
    /// layer list with per-layer precision overrides installed.  Any
    /// precision overrides the source workload carried are replaced by the
    /// genome's assignment (the optimizer owns the precision axis).
    pub fn decode(&self, g: &Genome) -> (AcceleratorConfig, Vec<Layer>) {
        let array = self.array_type(&g.prec);
        let cfg = AcceleratorConfig {
            pe_type: array,
            pe_rows: self.space.rows[g.hw[0]],
            pe_cols: self.space.cols[g.hw[1]],
            glb_kb: self.space.glb_kb[g.hw[2]],
            spad_ifmap_b: self.space.spad_ifmap_b[g.hw[3]],
            spad_filter_b: self.space.spad_filter_b[g.hw[4]],
            spad_psum_b: self.space.spad_psum_b[g.hw[5]],
            bandwidth_gbps: self.space.bandwidth_gbps[g.hw[6]],
        };
        let array_spec = cfg.quant();
        let mut layers = self.layers.to_vec();
        if g.prec.len() == 1 {
            for l in layers.iter_mut() {
                l.quant = None;
            }
        } else {
            for (l, &i) in layers.iter_mut().zip(&g.prec) {
                let spec = self.palette[i].spec();
                l.quant = if spec == array_spec { None } else { Some(spec) };
            }
        }
        (cfg, layers)
    }

    /// Per-layer precision labels of a genome (report surface): one label
    /// per layer in per-layer mode, a single label for a uniform design.
    pub fn precision_labels(&self, g: &Genome) -> Vec<String> {
        g.prec.iter().map(|&i| self.palette[i].label()).collect()
    }

    /// Uniform crossover: each gene swaps between the children with
    /// probability 1/2.
    pub fn crossover(&self, a: &Genome, b: &Genome, rng: &mut Rng) -> (Genome, Genome) {
        let mut c1 = a.clone();
        let mut c2 = b.clone();
        for i in 0..HW_GENES {
            if rng.f64() < 0.5 {
                std::mem::swap(&mut c1.hw[i], &mut c2.hw[i]);
            }
        }
        let n = c1.prec.len().min(c2.prec.len());
        for i in 0..n {
            if rng.f64() < 0.5 {
                std::mem::swap(&mut c1.prec[i], &mut c2.prec[i]);
            }
        }
        (c1, c2)
    }

    /// Mutate in place: each gene flips with probability `1/genes`; half
    /// of the flips take a ±1 step along the axis (local refinement on the
    /// smooth PPA landscape), half resample uniformly (escape hatch).  If
    /// the pass changed nothing, one random gene is forced so a child is
    /// never a clone of its parent.
    pub fn mutate(&self, g: &mut Genome, rng: &mut Rng) {
        let lens = self.axis_lens();
        let pm = 1.0 / self.genes() as f64;
        let mut changed = false;
        for i in 0..HW_GENES {
            if rng.f64() < pm {
                changed |= self.mutate_gene(&mut g.hw[i], lens[i], rng);
            }
        }
        let pal = self.palette.len();
        for gene in g.prec.iter_mut() {
            if rng.f64() < pm {
                changed |= self.mutate_gene(gene, pal, rng);
            }
        }
        if !changed {
            // Force one flip so a child is never a parent clone — unless
            // every gene sits on a length-1 axis (a fully degenerate
            // domain), in which case there is nothing to move.
            let movable = lens.iter().any(|&l| l > 1) || (pal > 1 && !g.prec.is_empty());
            while movable && !changed {
                let pick = rng.below(HW_GENES + g.prec.len());
                changed = if pick < HW_GENES {
                    self.mutate_gene(&mut g.hw[pick], lens[pick], rng)
                } else {
                    self.mutate_gene(&mut g.prec[pick - HW_GENES], pal, rng)
                };
            }
        }
    }

    /// One gene flip; returns whether the value actually moved.
    fn mutate_gene(&self, gene: &mut usize, len: usize, rng: &mut Rng) -> bool {
        if len <= 1 {
            return false;
        }
        let old = *gene;
        if rng.f64() < 0.5 {
            // ±1 step, clamped to the axis
            *gene = if rng.f64() < 0.5 {
                gene.saturating_sub(1)
            } else {
                (*gene + 1).min(len - 1)
            };
        } else {
            *gene = rng.below(len);
        }
        *gene != old
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ALL_PE_TYPES;

    fn layers() -> Vec<Layer> {
        vec![
            Layer::conv("c1", 3, 16, 32, 32, 3, 1, 1),
            Layer::dw("dw", 16, 16, 3, 1, 1),
            Layer::fc("fc", 256, 10),
        ]
    }

    fn space() -> DesignSpace {
        DesignSpace::tiny()
    }

    #[test]
    fn random_genomes_decode_to_valid_designs() {
        let s = space();
        let ls = layers();
        let search = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let g = search.random(&mut rng);
            assert_eq!(g.prec.len(), ls.len());
            let (cfg, decoded) = search.decode(&g);
            cfg.validate().unwrap();
            assert_eq!(decoded.len(), ls.len());
            // every override stays within the palette's specs
            for l in &decoded {
                if let Some(q) = l.quant {
                    assert!(ALL_PE_TYPES.iter().any(|t| t.spec() == q));
                    assert_ne!(q, cfg.quant(), "override equal to the array spec must be None");
                }
            }
        }
    }

    #[test]
    fn decode_is_deterministic_and_keyed() {
        let s = space();
        let ls = layers();
        let search = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        let mut rng = Rng::new(3);
        let g = search.random(&mut rng);
        let (c1, l1) = search.decode(&g);
        let (c2, l2) = search.decode(&g);
        assert_eq!(c1, c2);
        assert_eq!(l1, l2);
        assert_eq!(g.key(), g.clone().key());
        let h = search.random(&mut rng);
        if g != h {
            assert_ne!(g.key(), h.key());
        }
    }

    #[test]
    fn array_is_provisioned_for_the_widest_assigned_spec() {
        let s = space();
        let ls = layers();
        let palette = vec![
            PeType::from_spec(QuantSpec::int(4, 4)),
            PeType::Int16,
            PeType::LightPe1,
        ];
        let search = SearchSpace::new(&s, palette, &ls, true).unwrap();
        // all layers at INT4 -> array is the INT4 cell
        let g = Genome { hw: [0; HW_GENES], prec: vec![0, 0, 0] };
        let (cfg, _) = search.decode(&g);
        assert_eq!(cfg.quant(), QuantSpec::int(4, 4));
        // mixing INT4 with INT16 -> array widens to cover INT16
        let g = Genome { hw: [0; HW_GENES], prec: vec![0, 1, 0] };
        let (cfg, dec) = search.decode(&g);
        assert!(cfg.quant().act_bits >= 16 && cfg.quant().psum_bits >= 32);
        // the INT4 layers carry overrides, the INT16 layer matches the array
        assert!(dec[0].quant.is_some() && dec[2].quant.is_some());
        // mixing in a lightweight cell promotes the datapath kind
        let g = Genome { hw: [0; HW_GENES], prec: vec![0, 1, 2] };
        let (cfg, _) = search.decode(&g);
        assert!(cfg.quant().is_light());
        assert!(cfg.quant().act_bits >= 16);
        cfg.validate().unwrap();
    }

    #[test]
    fn uniform_mode_uses_one_gene_and_no_overrides() {
        let s = space();
        let ls = layers();
        let search = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, false).unwrap();
        assert_eq!(search.prec_len(), 1);
        let mut rng = Rng::new(5);
        let g = search.random(&mut rng);
        assert_eq!(g.prec.len(), 1);
        let (cfg, dec) = search.decode(&g);
        assert_eq!(cfg.pe_type, search.palette[g.prec[0]]);
        assert!(dec.iter().all(|l| l.quant.is_none()));
        // single-cell palettes degenerate to one gene even per-layer
        let one = SearchSpace::new(&s, vec![PeType::Int16], &ls, true).unwrap();
        assert_eq!(one.prec_len(), 1);
        assert_eq!(one.uniform_grid_len(), s.len());
    }

    #[test]
    fn variation_operators_stay_in_range_and_are_seeded() {
        let s = space();
        let ls = layers();
        let search = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        let lens = search.axis_lens();
        let mut rng = Rng::new(11);
        let a = search.random(&mut rng);
        let b = search.random(&mut rng);
        let (c1, c2) = search.crossover(&a, &b, &mut rng);
        for c in [&c1, &c2] {
            for (i, &g) in c.hw.iter().enumerate() {
                assert!(g < lens[i]);
            }
            for &p in &c.prec {
                assert!(p < search.palette.len());
            }
        }
        // crossover conserves the multiset of genes per position
        for i in 0..HW_GENES {
            let mut before = [a.hw[i], b.hw[i]];
            let mut after = [c1.hw[i], c2.hw[i]];
            before.sort_unstable();
            after.sort_unstable();
            assert_eq!(before, after);
        }
        // mutation always changes something and stays in range
        for seed in 0..50u64 {
            let mut rng = Rng::new(seed);
            let mut g = search.random(&mut rng);
            let orig = g.clone();
            search.mutate(&mut g, &mut rng);
            assert_ne!(g, orig, "seed {seed}: mutation must move the genome");
            for (i, &d) in g.hw.iter().enumerate() {
                assert!(d < lens[i]);
            }
            for &p in &g.prec {
                assert!(p < search.palette.len());
            }
        }
        // same seed, same stream
        let mut r1 = Rng::new(99);
        let mut r2 = Rng::new(99);
        assert_eq!(search.random(&mut r1), search.random(&mut r2));
    }

    #[test]
    fn corner_seeds_cover_extremes_per_cell() {
        let s = space();
        let ls = layers();
        let search = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &ls, true).unwrap();
        let seeds = search.corner_seeds();
        assert_eq!(seeds.len(), 3 * search.palette.len());
        let lens = search.axis_lens();
        for g in &seeds {
            assert!(g.prec.iter().all(|&p| p == g.prec[0]), "seeds are uniform-precision");
            let (cfg, _) = search.decode(g);
            cfg.validate().unwrap();
            for (i, &d) in g.hw.iter().enumerate() {
                assert!(d < lens[i]);
            }
        }
        // the all-min and all-max corners are present
        assert!(seeds.iter().any(|g| g.hw.iter().all(|&d| d == 0)));
        assert!(seeds
            .iter()
            .any(|g| g.hw.iter().zip(lens.iter()).all(|(&d, &l)| d == l - 1)));
    }

    #[test]
    fn empty_inputs_are_structured_errors() {
        let s = space();
        let ls = layers();
        let e = SearchSpace::new(&s, Vec::new(), &ls, true).unwrap_err();
        assert_eq!(e.kind(), "config");
        let empty: Vec<Layer> = Vec::new();
        let e = SearchSpace::new(&s, ALL_PE_TYPES.to_vec(), &empty, true).unwrap_err();
        assert_eq!(e.kind(), "workload");
        let mut bad = DesignSpace::tiny();
        bad.rows.clear();
        let e = SearchSpace::new(&bad, ALL_PE_TYPES.to_vec(), &ls, true).unwrap_err();
        assert!(e.to_string().contains("rows"), "{e}");
        // a quants-extended space is rejected, not silently ignored
        let quanted = DesignSpace::tiny().with_quants(ALL_PE_TYPES.to_vec());
        let e = SearchSpace::new(&quanted, ALL_PE_TYPES.to_vec(), &ls, true).unwrap_err();
        assert_eq!(e.kind(), "config");
        assert!(e.to_string().contains("quants"), "{e}");
    }
}
