//! # QAPPA — Quantization-Aware Power, Performance and Area modeling
//!
//! Reproduction of *"QAPPA: Quantization-Aware Power, Performance, and Area
//! Modeling of DNN Accelerators"* (Inci et al., cs.AR 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the DSE coordinator: design-space enumeration, the
//!   synthesis oracle fleet, k-fold CV over the AOT regression artifacts,
//!   batched prediction, Pareto extraction and figure regeneration.
//! * **L2 (python/compile/model.py)** — weighted polynomial ridge regression
//!   lowered once to HLO-text artifacts (`artifacts/*.hlo.txt`).
//! * **L1 (python/compile/kernels/poly.py)** — Pallas kernels for monomial
//!   feature expansion, fused predict and blocked Gram accumulation.
//!
//! Python never runs on the request path: the rust binary loads the HLO
//! artifacts through the PJRT CPU client (`runtime`) and is self-contained.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! | module        | role |
//! |---------------|------|
//! | [`config`]    | accelerator configurations, PE types, design spaces |
//! | [`synth`]     | gate-level synthesis oracle (Design Compiler stand-in) |
//! | [`rtl`]       | Verilog emitter + gate-level simulator (VCS stand-in) |
//! | [`dataflow`]  | row-stationary performance / traffic / energy model |
//! | [`workloads`] | VGG-16, ResNet-34, ResNet-50 layer tables |
//! | [`model`]     | PPA regression: features, native baseline, CV driver |
//! | [`runtime`]   | PJRT artifact loading + batched execution engine |
//! | [`coordinator`]| DSE pipeline, Pareto frontier, figure reports |
//! | [`util`]      | json / prng / stats / cli / thread-pool substrates |
//! | [`testkit`]   | property-testing mini-framework (proptest stand-in) |

pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod model;
pub mod rtl;
pub mod runtime;
pub mod synth;
pub mod testkit;
pub mod util;
pub mod workloads;
