//! # QAPPA — Quantization-Aware Power, Performance and Area modeling
//!
//! Reproduction of *"QAPPA: Quantization-Aware Power, Performance, and Area
//! Modeling of DNN Accelerators"* (Inci et al., cs.AR 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the DSE coordinator: design-space enumeration, the
//!   synthesis oracle fleet, k-fold CV over the AOT regression artifacts,
//!   batched prediction, Pareto extraction and figure regeneration.
//! * **L2 (python/compile/model.py)** — weighted polynomial ridge regression
//!   lowered once to HLO-text artifacts (`artifacts/*.hlo.txt`).
//! * **L1 (python/compile/kernels/poly.py)** — Pallas kernels for monomial
//!   feature expansion, fused predict and blocked Gram accumulation.
//!
//! Python never runs on the request path: the rust binary loads the HLO
//! artifacts through the PJRT CPU client (`runtime`) and is self-contained.
//!
//! ## Module map
//!
//! Each module corresponds to one piece of the paper's flow (README.md has
//! the end-to-end architecture diagram):
//!
//! | module         | paper section | role |
//! |----------------|---------------|------|
//! | [`api`]        | —    | typed service facade: `Qappa` sessions, request/response types, `QappaError`, the `qappa serve` JSON-lines loop (`docs/API.md`) |
//! | [`config`]     | §3.1 | accelerator configurations, PE types (FP32 / INT16 / LightPE), design-space axes |
//! | [`synth`]      | §3.2 | gate-level synthesis oracle (Design Compiler stand-in) producing ground-truth PPA |
//! | [`rtl`]        | §3.2 | Verilog emitter + gate-level simulator (VCS stand-in) for spot verification |
//! | [`dataflow`]   | §3.3 | row-stationary performance / traffic / energy model; groups-aware (dense, grouped, depthwise) |
//! | [`workloads`]  | §4   | built-in nets (VGG-16, ResNet-34/50, MobileNetV1/V2) + JSON model ingestion |
//! | [`model`]      | §3.4 | PPA regression: features, native baseline, CV driver |
//! | [`obs`]        | —    | observability: tracing spans with a pluggable `QAPPA_TRACE` sink + the process-wide metrics registry behind the `metrics` op (`docs/OBSERVABILITY.md`) |
//! | [`runtime`]    | §3.4 | PJRT artifact loading + batched execution engine |
//! | [`coordinator`]| §4   | streaming DSE pipeline (sharded sweeps, model cache, incremental Pareto), figure reports (Figs. 2-5) |
//! | [`opt`]        | —    | guided multi-objective optimizer: constraint-driven NSGA-II / random / hill-climb search over hardware x per-layer precision x model knobs (`docs/OPTIMIZER.md`) |
//! | [`accuracy`]   | —    | quantization-sensitivity accuracy model: noise-based proxy + measured sensitivity tables, the `accuracy` objective's backend (`docs/ACCURACY.md`) |
//! | [`util`]       | —    | json / prng / stats / cli / thread-pool substrates |
//! | [`testkit`]    | —    | property-testing mini-framework (proptest stand-in) with config/layer generators |
//!
//! ## Workloads
//!
//! The paper evaluates VGG-16 and ResNet-34/50. This crate additionally
//! models depthwise/grouped convolutions end-to-end ([`dataflow::Layer`]
//! carries a `groups` field through MAC, traffic and energy accounting),
//! ships MobileNetV1/V2 builders, and ingests arbitrary user networks from
//! JSON ([`workloads::from_json`]; schema in `docs/WORKLOADS.md`).
//!
//! ## Using QAPPA as a library / service
//!
//! The [`api`] module is the crate's public service layer: build a warm
//! [`api::Qappa`] session once, then issue typed `synth` / `fit` /
//! `explore` / `analyze` / `workloads` queries against it — models train
//! once per session and every query after that runs at sweep speed.
//! `qappa serve` exposes the same facade as a JSON-lines request loop on
//! stdin/stdout.  Every fallible public API in the crate returns
//! [`QappaError`], a structured error whose variants (`Config`,
//! `Workload`, `Backend`, `Model`, `Io`, `Protocol`) classify where a
//! request died.  Schemas and the wire protocol are documented in
//! `docs/API.md`.

pub mod accuracy;
pub mod api;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod model;
pub mod obs;
pub mod opt;
pub mod rtl;
pub mod runtime;
pub mod synth;
pub mod testkit;
pub mod util;
pub mod workloads;

pub use api::error::QappaError;
