//! `qappa` — the QAPPA coordinator CLI.
//!
//! Subcommands:
//!
//! * `synth`     — synthesize one configuration, print ground-truth PPA
//! * `fit`       — train the PPA models (k-fold CV) and print the CV table
//! * `fig2`      — model-accuracy reproduction (actual vs estimated)
//! * `dse` / `explore` — full design-space exploration for a workload
//!   (built-in name or JSON model file; Fig 3-5)
//! * `figures`   — regenerate all paper figures into `figures/*.csv`
//! * `rtl`       — emit generated Verilog for a configuration
//! * `verify`    — run the gate-level simulator against golden models
//! * `workloads` — print the layer tables and MAC totals
//!
//! Backend: `--backend xla` (default if `artifacts/` is present) drives the
//! AOT-compiled PJRT artifacts; `--backend native` uses the pure-Rust
//! fallback.

use std::sync::Arc;

use qappa::config::{AcceleratorConfig, PeType, ALL_PE_TYPES};
use qappa::coordinator::report::{
    dse_scatter_table, dse_stats_table, dse_summary_table, fig2_accuracy, fig2_table,
    multi_summary_table, sweep_stats_table, workload_table,
};
use qappa::coordinator::{
    run_dse, run_dse_multi, DseOptions, ModelStore, NamedWorkload,
};
use qappa::model::native::NativeBackend;
use qappa::model::Backend;
use qappa::runtime::{Engine, XlaBackend};
use qappa::util::cli::Args;
use qappa::util::table::Table;
use qappa::workloads;

fn main() {
    let args = match Args::from_env(&["help", "all", "clean", "quiet", "scatter", "stats"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    let code = match dispatch(&sub, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(sub: &str, args: &Args) -> Result<(), String> {
    match sub {
        "synth" => cmd_synth(args),
        "fit" => cmd_fit(args),
        "fig2" | "accuracy" => cmd_fig2(args),
        "dse" | "explore" => cmd_dse(args),
        "figures" => cmd_figures(args),
        "rtl" => cmd_rtl(args),
        "verify" => cmd_verify(args),
        "workloads" => cmd_workloads(args),
        "analyze" => cmd_analyze(args),
        _ => {
            args.finish().ok();
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
qappa — quantization-aware PPA modeling of DNN accelerators

USAGE: qappa <subcommand> [options]

SUBCOMMANDS
  synth     --pe-type T [--rows N --cols N --glb-kb N --spad-if B --spad-w B
            --spad-ps B --bw G]          synthesize one config (ground truth)
  fit       [--backend xla|native --train N --k N --seed S]
                                         train PPA models, print CV tables
  fig2      [--backend ... --train N --holdout N --out DIR]
                                         model accuracy vs synthesis (Fig. 2)
  dse       --workload W[,W2,...] [--backend ... --train N --chunk N --topk K
            --out DIR --scatter --stats]
            (alias: explore)             design-space exploration (Fig. 3-5);
                                         a comma list sweeps all workloads in
                                         one streaming pass (models trained
                                         once, cross-workload summary table)
  figures   [--all --backend ... --out DIR]
                                         regenerate every figure into CSVs
  rtl       --pe-type T [--out FILE]     emit generated Verilog
  verify    [--vectors N]                gate-level sim vs golden models
  workloads [--workload W]               print layer tables / MAC totals
  analyze   --workload W --pe-type T [config flags as in synth]
                                         per-layer latency/energy breakdown

WORKLOADS (--workload W)
  Built-in: vgg16, resnet34, resnet50, mobilenetv1, mobilenetv2.
  Or a path to a JSON model file (depthwise/grouped convs supported);
  the schema is documented in docs/WORKLOADS.md.

Artifacts: set QAPPA_ARTIFACTS or run from the repo root (default:
./artifacts). `--backend native` needs no artifacts.

Tracing: set QAPPA_TRACE=1 to print per-phase wall times (training,
per-shard predict and dataflow evaluation).
";

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

fn parse_config(args: &Args) -> Result<AcceleratorConfig, String> {
    let ty = PeType::parse(args.require("pe-type").map_err(|e| e.to_string())?)
        .ok_or("unknown --pe-type (fp32|int16|lightpe1|lightpe2)")?;
    let mut cfg = AcceleratorConfig::default_with(ty);
    cfg.pe_rows = args.get("rows", cfg.pe_rows).map_err(|e| e.to_string())?;
    cfg.pe_cols = args.get("cols", cfg.pe_cols).map_err(|e| e.to_string())?;
    cfg.glb_kb = args.get("glb-kb", cfg.glb_kb).map_err(|e| e.to_string())?;
    cfg.spad_ifmap_b = args.get("spad-if", cfg.spad_ifmap_b).map_err(|e| e.to_string())?;
    cfg.spad_filter_b = args.get("spad-w", cfg.spad_filter_b).map_err(|e| e.to_string())?;
    cfg.spad_psum_b = args.get("spad-ps", cfg.spad_psum_b).map_err(|e| e.to_string())?;
    cfg.bandwidth_gbps = args.get("bw", cfg.bandwidth_gbps).map_err(|e| e.to_string())?;
    cfg.validate()?;
    Ok(cfg)
}

enum AnyBackend {
    Native(NativeBackend),
    Xla(XlaBackend, Arc<Engine>),
}

impl AnyBackend {
    fn get(&self) -> &dyn Backend {
        match self {
            AnyBackend::Native(b) => b,
            AnyBackend::Xla(b, _) => b,
        }
    }
}

fn make_backend(args: &Args) -> Result<AnyBackend, String> {
    let dir = qappa::runtime::ArtifactRuntime::artifacts_dir_default();
    let choice = args.opt("backend").map(str::to_string).unwrap_or_else(|| {
        if dir.join("manifest.json").exists() {
            "xla".into()
        } else {
            "native".into()
        }
    });
    match choice.as_str() {
        "native" => Ok(AnyBackend::Native(NativeBackend::new(7))),
        "xla" => {
            let engine = Arc::new(Engine::start(&dir).map_err(|e| {
                format!("starting XLA engine from {}: {e}", dir.display())
            })?);
            eprintln!(
                "[qappa] XLA engine up (d={}, B={}, N_fit={}) from {}",
                engine.d,
                engine.b_predict,
                engine.n_fit,
                dir.display()
            );
            Ok(AnyBackend::Xla(XlaBackend::new(engine.clone()), engine))
        }
        other => Err(format!("unknown backend '{other}'")),
    }
}

fn dse_options(args: &Args) -> Result<DseOptions, String> {
    let mut opts = DseOptions::default();
    opts.train_per_type = args.get("train", opts.train_per_type).map_err(|e| e.to_string())?;
    opts.cv.k = args.get("k", opts.cv.k).map_err(|e| e.to_string())?;
    opts.seed = args.get("seed", opts.seed).map_err(|e| e.to_string())?;
    opts.workers = args.get("workers", opts.workers).map_err(|e| e.to_string())?;
    opts.sigma = args.get("sigma", opts.sigma).map_err(|e| e.to_string())?;
    opts.chunk = args.get("chunk", opts.chunk).map_err(|e| e.to_string())?;
    opts.topk = args.get("topk", opts.topk).map_err(|e| e.to_string())?;
    Ok(opts)
}

// ---------------------------------------------------------------------------
// subcommands
// ---------------------------------------------------------------------------

fn cmd_synth(args: &Args) -> Result<(), String> {
    let cfg = parse_config(args)?;
    args.finish().map_err(|e| e.to_string())?;
    let ppa = qappa::synth::synthesize(&cfg);
    let clean = qappa::synth::synthesize_clean(&cfg);
    let mut t = Table::new(&["metric", "synthesized", "jitter-free"]);
    t.row(vec!["power_mw".into(), format!("{:.3}", ppa.power_mw), format!("{:.3}", clean.power_mw)]);
    t.row(vec!["fmax_mhz".into(), format!("{:.1}", ppa.fmax_mhz), format!("{:.1}", clean.fmax_mhz)]);
    t.row(vec!["area_mm2".into(), format!("{:.4}", ppa.area_mm2), format!("{:.4}", clean.area_mm2)]);
    println!("config: {}", cfg.key());
    print!("{}", t.render());
    Ok(())
}

fn cmd_fit(args: &Args) -> Result<(), String> {
    let opts = dse_options(args)?;
    let backend = make_backend(args)?;
    args.finish().map_err(|e| e.to_string())?;
    let models = qappa::coordinator::explorer::train_models(backend.get(), &opts)?;
    for ty in ALL_PE_TYPES {
        let m = &models[&ty];
        println!(
            "\n{}: selected degree={} lambda={} (n={}, backend={})",
            ty.label(),
            m.degree,
            m.lambda,
            m.n_train,
            backend.get().name()
        );
        let mut t = Table::new(&["degree", "lambda", "cv_mse"]);
        for e in &m.cv_table {
            t.row(vec![
                e.degree.to_string(),
                format!("{:e}", e.lambda),
                format!("{:.5}", e.mse),
            ]);
        }
        print!("{}", t.render());
    }
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<(), String> {
    let opts = dse_options(args)?;
    let holdout = args.get("holdout", 128usize).map_err(|e| e.to_string())?;
    let out = args.opt("out").map(str::to_string);
    let backend = make_backend(args)?;
    args.finish().map_err(|e| e.to_string())?;
    let rows = fig2_accuracy(backend.get(), &opts, holdout)?;
    let t = fig2_table(&rows);
    println!("Figure 2 — actual vs estimated PPA (backend={})", backend.get().name());
    print!("{}", t.render());
    if let Some(dir) = out {
        let path = format!("{dir}/fig2_accuracy.csv");
        t.write_csv(&path).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// CSV-safe file stem for a (possibly user-supplied) workload name.
fn sanitize_name(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

fn cmd_dse(args: &Args) -> Result<(), String> {
    let spec = args.require("workload").map_err(|e| e.to_string())?.to_string();
    let specs: Vec<&str> = spec.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if specs.is_empty() {
        return Err("--workload: empty workload list".into());
    }
    if specs.len() > 1 {
        return cmd_dse_multi(args, &specs);
    }
    let (wl, layers) = workloads::load(specs[0])?;
    let opts = dse_options(args)?;
    let out = args.opt("out").map(str::to_string);
    let want_scatter = args.flag("scatter");
    let want_stats = args.flag("stats");
    let backend = make_backend(args)?;
    args.finish().map_err(|e| e.to_string())?;

    let t0 = std::time::Instant::now();
    let res = run_dse(backend.get(), &layers, &wl, &opts)?;
    let dt = t0.elapsed().as_secs_f64();

    println!(
        "DSE over {} ({} layers) — {} configs/type, backend={}, {:.2}s",
        wl,
        layers.len(),
        opts.space.len(),
        backend.get().name(),
        dt
    );
    println!("anchor (best INT16 perf/area): {}", res.anchor.cfg.key());
    print!("{}", dse_summary_table(&res).render());
    if want_stats {
        print!("{}", dse_stats_table(&res).render());
    }
    if let AnyBackend::Xla(_, engine) = &backend {
        let s = &engine.stats;
        use std::sync::atomic::Ordering::Relaxed;
        println!(
            "[engine] predict: {} rows in {} batches ({} padded rows), fit: {}, loss: {}",
            s.predict_rows.load(Relaxed),
            s.predict_batches.load(Relaxed),
            s.predict_padded_rows.load(Relaxed),
            s.fit_calls.load(Relaxed),
            s.loss_calls.load(Relaxed)
        );
    }
    if let Some(dir) = out {
        let stem = sanitize_name(&wl);
        let summary_path = format!("{dir}/{stem}_summary.csv");
        dse_summary_table(&res).write_csv(&summary_path).map_err(|e| e.to_string())?;
        println!("wrote {summary_path}");
        if want_scatter {
            let scatter_path = format!("{dir}/{stem}_scatter.csv");
            dse_scatter_table(&res).write_csv(&scatter_path).map_err(|e| e.to_string())?;
            println!("wrote {scatter_path}");
        }
    }
    Ok(())
}

/// `qappa explore --workload a,b,c`: one streaming pass over the grid per
/// PE type, every workload evaluated against each predicted shard; models
/// trained once and shared through the `ModelStore`.
fn cmd_dse_multi(args: &Args, specs: &[&str]) -> Result<(), String> {
    let mut named = Vec::with_capacity(specs.len());
    for spec in specs {
        let (name, layers) = workloads::load(spec)?;
        named.push(NamedWorkload::new(name, layers));
    }
    let opts = dse_options(args)?;
    let out = args.opt("out").map(str::to_string);
    let want_stats = args.flag("stats");
    if args.flag("scatter") {
        return Err(
            "--scatter needs the full point set; it is only available for \
             single-workload runs"
                .into(),
        );
    }
    let backend = make_backend(args)?;
    args.finish().map_err(|e| e.to_string())?;

    let store = ModelStore::new();
    let t0 = std::time::Instant::now();
    let summaries = run_dse_multi(backend.get(), &store, &named, &opts)?;
    let dt = t0.elapsed().as_secs_f64();

    println!(
        "DSE over {} workloads ({}) — {} configs/type, chunk={}, top-k={}, backend={}, {:.2}s",
        named.len(),
        named.iter().map(|w| w.name.as_str()).collect::<Vec<_>>().join(", "),
        opts.space.len(),
        opts.chunk,
        opts.topk,
        backend.get().name(),
        dt
    );
    for s in &summaries {
        println!(
            "anchor[{}] (best INT16 perf/area): {}",
            s.workload,
            s.anchor.cfg.key()
        );
    }
    print!("{}", multi_summary_table(&summaries).render());
    println!(
        "[store] models trained: {} (cache hits: {})",
        store.misses(),
        store.hits()
    );
    let peak = summaries
        .iter()
        .flat_map(|s| s.stats.values().map(|st| st.peak_resident))
        .max()
        .unwrap_or(0);
    println!(
        "[engine] peak resident points: {} of {} evaluated per (type, workload)",
        peak,
        opts.space.len()
    );
    if want_stats {
        print!("{}", sweep_stats_table(&summaries).render());
    }
    if let Some(dir) = out {
        let path = format!("{dir}/multi_summary.csv");
        multi_summary_table(&summaries).write_csv(&path).map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<(), String> {
    let out = args.opt("out").unwrap_or("figures").to_string();
    let opts = dse_options(args)?;
    let backend = make_backend(args)?;
    let _all = args.flag("all");
    args.finish().map_err(|e| e.to_string())?;

    // Fig 2.
    let rows = fig2_accuracy(backend.get(), &opts, 128)?;
    let t2 = fig2_table(&rows);
    println!("Figure 2 — model accuracy");
    print!("{}", t2.render());
    t2.write_csv(&format!("{out}/fig2_accuracy.csv")).map_err(|e| e.to_string())?;

    // Figs 3-5.
    for (fig, wl) in [(3, "vgg16"), (4, "resnet34"), (5, "resnet50")] {
        let layers = workloads::by_name(wl).unwrap();
        let res = run_dse(backend.get(), &layers, wl, &opts)?;
        println!("\nFigure {fig} — {wl} design space (anchor {})", res.anchor.cfg.key());
        let ts = dse_summary_table(&res);
        print!("{}", ts.render());
        ts.write_csv(&format!("{out}/fig{fig}_{wl}_summary.csv")).map_err(|e| e.to_string())?;
        dse_scatter_table(&res)
            .write_csv(&format!("{out}/fig{fig}_{wl}_scatter.csv"))
            .map_err(|e| e.to_string())?;
    }
    println!("\nwrote CSVs under {out}/");
    Ok(())
}

fn cmd_rtl(args: &Args) -> Result<(), String> {
    let cfg = parse_config(args)?;
    let out = args.opt("out").map(str::to_string);
    args.finish().map_err(|e| e.to_string())?;
    let v = qappa::rtl::verilog::generate(&cfg);
    match out {
        Some(path) => {
            std::fs::write(&path, &v).map_err(|e| e.to_string())?;
            println!("wrote {} ({} bytes)", path, v.len());
        }
        None => print!("{v}"),
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<(), String> {
    let n = args.get("vectors", 500usize).map_err(|e| e.to_string())?;
    args.finish().map_err(|e| e.to_string())?;
    println!("gate-level verification ({n} random vectors each):");
    let act = qappa::rtl::sim::verify_int16_multiplier(n, 0xc0ffee)?;
    println!("  int16 multiplier  OK   (activity {:.3})", act);
    for w in [20u32, 24] {
        let act = qappa::rtl::sim::verify_light_term(w, n, 0xbeef)?;
        println!("  light term w={w}    OK   (activity {:.3})", act);
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<(), String> {
    let spec = args.require("workload").map_err(|e| e.to_string())?.to_string();
    let (_wl, layers) = workloads::load(&spec)?;
    let cfg = parse_config(args)?;
    args.finish().map_err(|e| e.to_string())?;

    let ep = qappa::synth::oracle::energy_params(&cfg);
    let ppa = qappa::synth::synthesize_clean(&cfg);
    println!("config: {}  ({:.2} mW, {:.0} MHz, {:.3} mm2)", cfg.key(),
             ppa.power_mw, ppa.fmax_mhz, ppa.area_mm2);
    let mut t = Table::new(&[
        "layer", "MACs_M", "cycles_k", "util", "stall_%", "dram_MB",
        "energy_mJ", "E_compute", "E_dram", "E_other",
    ]);
    let mut total_lat = 0.0;
    let mut total_e = 0.0;
    for l in &layers {
        let mapped = qappa::dataflow::map_layer(&cfg, &ep, l);
        let traffic = qappa::dataflow::layer_traffic(&cfg, l, &mapped);
        let perf = qappa::dataflow::rs::apply_bandwidth(&cfg, &ep, l, &mapped, traffic.dram_bytes);
        let e = qappa::dataflow::layer_energy(&cfg, &ep, l, &perf, &traffic);
        total_lat += perf.latency_s(ep.fmax_mhz);
        total_e += e.total_mj();
        t.row(vec![
            l.name.clone(),
            format!("{:.1}", l.macs() as f64 / 1e6),
            format!("{:.0}", perf.cycles as f64 / 1e3),
            format!("{:.2}", perf.utilization),
            format!("{:.0}", 100.0 * perf.stall_cycles as f64 / perf.cycles.max(1) as f64),
            format!("{:.2}", traffic.dram_bytes as f64 / 1e6),
            format!("{:.3}", e.total_mj()),
            format!("{:.3}", e.compute_mj),
            format!("{:.3}", e.dram_mj),
            format!("{:.3}", e.glb_mj + e.noc_mj + e.leakage_mj),
        ]);
    }
    print!("{}", t.render());
    println!(
        "total: {:.2} ms/inference ({:.1} inf/s), {:.2} mJ/inference",
        total_lat * 1e3,
        1.0 / total_lat,
        total_e
    );
    Ok(())
}

fn cmd_workloads(args: &Args) -> Result<(), String> {
    let detail = args.opt("workload").map(str::to_string);
    args.finish().map_err(|e| e.to_string())?;
    match detail {
        Some(spec) => {
            let (name, layers) = workloads::load(&spec)?;
            let macs: u64 = layers.iter().map(|l| l.macs()).sum();
            println!("{name}: {} layers, {:.2} GMACs", layers.len(), macs as f64 / 1e9);
            print!("{}", workload_table(&layers).render());
        }
        None => {
            for name in workloads::WORKLOAD_NAMES {
                let layers = workloads::by_name(name).unwrap();
                let macs: u64 = layers.iter().map(|l| l.macs()).sum();
                let dw = layers.iter().filter(|l| l.is_depthwise()).count();
                println!(
                    "{name}: {} layers ({dw} depthwise), {:.2} GMACs",
                    layers.len(),
                    macs as f64 / 1e9
                );
            }
            println!("\n(`workloads --workload W` prints the per-layer table)");
        }
    }
    Ok(())
}
