//! `qappa` — the QAPPA coordinator CLI.
//!
//! A thin client of the [`qappa::api`] service facade: every subcommand
//! parses flags into typed requests, runs them against a [`Qappa`] session
//! and renders the response.
//!
//! Subcommands:
//!
//! * `synth`     — synthesize one configuration, print ground-truth PPA
//! * `fit`       — train the PPA models (k-fold CV) and print the CV table
//! * `fig2`      — model-accuracy reproduction (actual vs estimated)
//! * `dse` / `explore` — full design-space exploration for a workload
//!   (built-in name or JSON model file; Fig 3-5)
//! * `optimize`  — guided multi-objective search over hardware x per-layer
//!   precision under constraints and a budget (docs/OPTIMIZER.md)
//! * `figures`   — regenerate all paper figures into `figures/*.csv`
//! * `rtl`       — emit generated Verilog for a configuration
//! * `verify`    — run the gate-level simulator against golden models
//! * `workloads` — print the layer tables and MAC totals
//! * `serve`     — JSON-lines request loop on stdin/stdout, or a concurrent
//!   TCP endpoint with `--listen` (docs/API.md, docs/SERVE.md)
//! * `loadgen`   — drive a serve endpoint with N lockstep connections and
//!   print a latency/throughput report (docs/SERVE.md)
//!
//! Backend: `--backend xla` (default if `artifacts/` is present) drives the
//! AOT-compiled PJRT artifacts; `--backend native` uses the pure-Rust
//! fallback.

use std::sync::Arc;

use qappa::api::{
    process_store, run_loadgen, AnalyzeRequest, BackendChoice, Constraints, DispatchOptions,
    FitRequest, LoadgenOptions, OptimizeRequest, PrecisionRequest, Qappa, QappaBuilder,
    QappaError, RequestMix, ResponseBody, ServeOptions, ServeResponse, SynthRequest, TcpServer,
    TransportOptions, WorkloadsRequest, WorkloadsResponse,
};
use qappa::config::{AcceleratorConfig, MacKind, PeType};
use qappa::coordinator::precision::parse_bits_axis;
use qappa::coordinator::report::{
    dse_scatter_table, dse_stats_table, dse_summary_table, fig2_table, multi_summary_table,
    opt_convergence_table, opt_frontier_table, precision_summary_table, sweep_stats_table,
    workload_table,
};
use qappa::coordinator::{DesignSpace, DseOptions, NamedWorkload, SweepStats};
use qappa::util::cli::Args;
use qappa::util::table::Table;
use qappa::workloads;

fn main() {
    let flags =
        ["help", "all", "clean", "cold", "no-coalesce", "quiet", "scatter", "stats", "uniform"];
    let args = match Args::from_env(&flags) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    let code = match dispatch(&sub, &args) {
        Some(Ok(())) => 0,
        Some(Err(e)) => {
            eprintln!("error: {e}");
            1
        }
        None => {
            eprintln!("error: unknown subcommand '{sub}'");
            eprintln!("run `qappa help` for the subcommand list");
            2
        }
    };
    std::process::exit(code);
}

/// `None` = unknown subcommand (the caller prints the error and exits 2);
/// `help` and the no-subcommand default still succeed with the usage text.
fn dispatch(sub: &str, args: &Args) -> Option<Result<(), QappaError>> {
    Some(match sub {
        "synth" => cmd_synth(args),
        "fit" => cmd_fit(args),
        "fig2" | "accuracy" => cmd_fig2(args),
        "dse" | "explore" => cmd_dse(args),
        "optimize" => cmd_optimize(args),
        "figures" => cmd_figures(args),
        "rtl" => cmd_rtl(args),
        "verify" => cmd_verify(args),
        "workloads" => cmd_workloads(args),
        "analyze" => cmd_analyze(args),
        "serve" => cmd_serve(args),
        "loadgen" => cmd_loadgen(args),
        "metrics" => cmd_metrics(args),
        "help" => {
            args.finish().ok();
            print!("{}", HELP);
            Ok(())
        }
        _ => return None,
    })
}

const HELP: &str = "\
qappa — quantization-aware PPA modeling of DNN accelerators

USAGE: qappa <subcommand> [options]

SUBCOMMANDS
  synth     --pe-type T [--rows N --cols N --glb-kb N --spad-if B --spad-w B
            --spad-ps B --bw G]          synthesize one config (ground truth)
  fit       [--backend xla|native --train N --k N --seed S]
                                         train PPA models, print CV tables
  fig2      [--backend ... --train N --holdout N --out DIR]
                                         model accuracy vs synthesis (Fig. 2)
  dse       --workload W[,W2,...] [--backend ... --train N --chunk N --topk K
            --out DIR --scatter --stats]
            (alias: explore)             design-space exploration (Fig. 3-5);
                                         a comma list sweeps all workloads in
                                         one streaming pass (models trained
                                         once, cross-workload summary table)
            [--act-bits A --wt-bits W [--psum-bits P|auto] [--mac M]
             --precision SPEC,SPEC,...]  precision-grid DSE: sweep arbitrary
                                         bit widths (ranges LO:HI[:STEP] or
                                         comma lists; --mac fp|int|light<n>)
                                         and/or explicit precision labels
                                         through one unified cross-precision
                                         model, one report row per precision
                                         cell (docs/PRECISION.md)
  optimize  --workload W [--objectives O1,O2[,O3] --budget N --pop N --strategy
            nsga2|random|hillclimb --max-area-mm2 X --max-power-mw X
            --max-latency-ms X --min-bits B --min-accuracy A --uniform
            --sensitivity FILE --width-mults M,... --depth-mults M,...
            --phase prefill|decode --ctx N
            --precision SPEC,... | --act-bits/--wt-bits/... --out DIR]
                                         guided multi-objective search over
                                         hardware x model knobs x per-layer
                                         precision: NSGA-II under an
                                         evaluation budget and hard
                                         constraints, frontier + convergence
                                         report
                                         (docs/OPTIMIZER.md); objectives:
                                         latency, energy, area, power,
                                         perf/area, perf/energy, edp,
                                         accuracy (noise-model estimate, or
                                         a measured --sensitivity table —
                                         docs/ACCURACY.md); --width-mults /
                                         --depth-mults add channel-width and
                                         depth multipliers to the genome
  figures   [--all --backend ... --out DIR]
                                         regenerate every figure into CSVs
  rtl       --pe-type T [--out FILE]     emit generated Verilog
  verify    [--vectors N]                gate-level sim vs golden models
  workloads [--workload W]               print layer tables / MAC totals
  analyze   --workload W --pe-type T [config flags as in synth]
            [--phase prefill|decode|both --ctx N --accuracy]
                                         per-layer latency/energy breakdown;
                                         --accuracy appends the noise-model
                                         accuracy estimate (docs/ACCURACY.md);
                                         --phase shapes transformer workloads
                                         for prefill (ctx-token prompt) or
                                         decode (1 token vs a ctx-token KV
                                         cache) and prints a phase summary
                                         with KV-cache DRAM traffic; 'both'
                                         composes prefill + ctx x decode
  serve     [--backend ... --train N --concurrency N]
            [--listen HOST:PORT --max-connections N --max-inflight N
             --max-line-bytes B --no-coalesce]
                                         JSON-lines request loop on
                                         stdin/stdout against one warm
                                         session (models trained once across
                                         all requests); protocol and worked
                                         examples in docs/API.md.
                                         --listen serves TCP clients
                                         concurrently over one shared model
                                         store (bounded admission, request
                                         coalescing, per-connection
                                         cancellation; EOF on stdin drains
                                         and exits) — docs/SERVE.md
  loadgen   [--addr HOST:PORT | session flags] [--connections N --requests M
            --mix explore|analyze|mixed --cold --connect-timeout-ms T]
                                         drive a serve endpoint with N
                                         lockstep connections x M requests,
                                         print one JSON line with latency
                                         percentiles and throughput (spawns
                                         an in-process server when --addr is
                                         absent; --cold skips the untimed
                                         warm-up request) — docs/SERVE.md
  metrics   [--addr HOST:PORT]           print one JSON snapshot of the
                                         process-wide metrics registry
                                         (counters, gauges, latency
                                         histograms); --addr queries a live
                                         serve endpoint over the `metrics`
                                         wire op — docs/OBSERVABILITY.md

WORKLOADS (--workload W)
  Built-in CNNs: vgg16, resnet34, resnet50, mobilenetv1, mobilenetv2.
  Built-in transformers: opt-1.3b, llama2-7b (decoder blocks with
  matmul/attention layers; shape with --phase/--ctx).
  Or a path to a JSON model file (depthwise/grouped convs and
  matmul/attention layers supported); schema in docs/WORKLOADS.md.

Artifacts: set QAPPA_ARTIFACTS or run from the repo root (default:
./artifacts). `--backend native` needs no artifacts.

Design space: `--space default|tiny` picks the swept hardware grid
(paper-scale by default; `tiny` is the 64-point smoke grid).

Progress/stats lines ([store], [engine], [trace]) go to stderr, so piped
stdout is always a parseable report.

Tracing: set QAPPA_TRACE=1 to print per-phase wall times (training,
per-shard predict and dataflow evaluation) to stderr, or QAPPA_TRACE=PATH
to append JSON-lines span events to PATH (docs/OBSERVABILITY.md).

Stats: `dse`/`explore`/`optimize` accept --stats-json PATH to dump the
process metrics snapshot after the run ('-' writes one line to stderr).
";

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

fn parse_config(args: &Args) -> Result<AcceleratorConfig, QappaError> {
    let ty = PeType::parse(args.require("pe-type")?).ok_or_else(|| {
        QappaError::Config(
            "unknown --pe-type (fp32|int16|lightpe1|lightpe2 or a<act>w<wt>p<psum>[-mac], \
             e.g. a8w4p20-light1)"
                .into(),
        )
    })?;
    let mut cfg = AcceleratorConfig::default_with(ty);
    cfg.pe_rows = args.get("rows", cfg.pe_rows)?;
    cfg.pe_cols = args.get("cols", cfg.pe_cols)?;
    cfg.glb_kb = args.get("glb-kb", cfg.glb_kb)?;
    cfg.spad_ifmap_b = args.get("spad-if", cfg.spad_ifmap_b)?;
    cfg.spad_filter_b = args.get("spad-w", cfg.spad_filter_b)?;
    cfg.spad_psum_b = args.get("spad-ps", cfg.spad_psum_b)?;
    cfg.bandwidth_gbps = args.get("bw", cfg.bandwidth_gbps)?;
    cfg.validate()?;
    Ok(cfg)
}

/// Build a session from the model/backend flags (`--backend --train --k
/// --seed --workers --sigma --chunk --topk --space`), defaults from
/// [`DseOptions::default`].  The backend starts lazily on first use.
fn session_from(args: &Args) -> Result<Qappa, QappaError> {
    Ok(builder_from(args)?.build())
}

/// The flag parsing behind [`session_from`], exposed so the network serve
/// path can inject the process-wide shared store before building.
fn builder_from(args: &Args) -> Result<QappaBuilder, QappaError> {
    let d = DseOptions::default();
    let mut b = Qappa::builder()
        .train_per_type(args.get("train", d.train_per_type)?)
        .cv_k(args.get("k", d.cv.k)?)
        .seed(args.get("seed", d.seed)?)
        .workers(args.get("workers", d.workers)?)
        .sigma(args.get("sigma", d.sigma)?)
        .chunk(args.get("chunk", d.chunk)?)
        .topk(args.get("topk", d.topk)?);
    if let Some(space) = args.opt("space") {
        b = b.space(match space {
            "default" | "paper" => DesignSpace::default(),
            "tiny" => DesignSpace::tiny(),
            other => {
                return Err(QappaError::Config(format!(
                    "--space: unknown design space '{other}' (expected default|tiny)"
                )))
            }
        });
    }
    if let Some(choice) = args.opt("backend") {
        b = b.backend(BackendChoice::parse(choice)?);
    }
    Ok(b)
}

fn write_csv(t: &Table, path: &str) -> Result<(), QappaError> {
    t.write_csv(path).map_err(|e| QappaError::io(format!("writing {path}"), e))
}

/// `--stats-json DEST`: dump the process metrics registry snapshot after a
/// run.  `-` writes one JSON line to stderr (stdout stays a pinned
/// report); anything else is a file path.
fn emit_stats_json(dest: Option<&str>) -> Result<(), QappaError> {
    let Some(dest) = dest else { return Ok(()) };
    let line = qappa::obs::registry().snapshot().to_json().to_string();
    if dest == "-" {
        eprintln!("{line}");
    } else {
        std::fs::write(dest, format!("{line}\n"))
            .map_err(|e| QappaError::io(format!("writing {dest}"), e))?;
        qappa::obs::diag("qappa", format_args!("wrote metrics snapshot to {dest}"));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// subcommands
// ---------------------------------------------------------------------------

fn cmd_synth(args: &Args) -> Result<(), QappaError> {
    let cfg = parse_config(args)?;
    args.finish()?;
    let session = Qappa::builder().build();
    let resp = session.synth(&SynthRequest { config: cfg })?;
    let (ppa, clean) = (&resp.synthesized, &resp.jitter_free);
    let mut t = Table::new(&["metric", "synthesized", "jitter-free"]);
    t.row(vec!["power_mw".into(), format!("{:.3}", ppa.power_mw), format!("{:.3}", clean.power_mw)]);
    t.row(vec!["fmax_mhz".into(), format!("{:.1}", ppa.fmax_mhz), format!("{:.1}", clean.fmax_mhz)]);
    t.row(vec!["area_mm2".into(), format!("{:.4}", ppa.area_mm2), format!("{:.4}", clean.area_mm2)]);
    println!("config: {}", resp.config.key());
    print!("{}", t.render());
    Ok(())
}

fn cmd_fit(args: &Args) -> Result<(), QappaError> {
    let session = session_from(args)?;
    args.finish()?;
    let resp = session.fit(&FitRequest::default())?;
    for m in &resp.models {
        println!(
            "\n{}: selected degree={} lambda={} (n={}, backend={})",
            m.pe_type.label(),
            m.degree,
            m.lambda,
            m.n_train,
            resp.backend
        );
        let mut t = Table::new(&["degree", "lambda", "cv_mse"]);
        for e in &m.cv {
            t.row(vec![
                e.degree.to_string(),
                format!("{:e}", e.lambda),
                format!("{:.5}", e.mse),
            ]);
        }
        print!("{}", t.render());
    }
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<(), QappaError> {
    let session = session_from(args)?;
    let holdout = args.get("holdout", 128usize)?;
    let out = args.opt("out").map(str::to_string);
    let backend_name = session.backend_name()?;
    args.finish()?;
    let rows = session.accuracy(holdout)?;
    let t = fig2_table(&rows);
    println!("Figure 2 — actual vs estimated PPA (backend={backend_name})");
    print!("{}", t.render());
    if let Some(dir) = out {
        let path = format!("{dir}/fig2_accuracy.csv");
        write_csv(&t, &path)?;
        println!("wrote {path}");
    }
    Ok(())
}

/// CSV-safe file stem for a (possibly user-supplied) workload name.
fn sanitize_name(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

/// Collect the precision-grid flags (`--act-bits --wt-bits --psum-bits
/// --mac --precision`); `None` when the run is a classic per-type sweep.
fn parse_precision_flags(args: &Args) -> Result<Option<PrecisionRequest>, QappaError> {
    let act = args.opt("act-bits").map(str::to_string);
    let wt = args.opt("wt-bits").map(str::to_string);
    let psum = args.opt("psum-bits").map(str::to_string);
    let mac = args.opt("mac").map(str::to_string);
    let types = args.opt("precision").map(str::to_string);
    if act.is_none() && wt.is_none() && psum.is_none() && mac.is_none() && types.is_none() {
        return Ok(None);
    }
    let mut req = PrecisionRequest::default();
    if let Some(s) = act {
        req.act_bits = parse_bits_axis(&s, "act-bits")?;
    }
    if let Some(s) = wt {
        req.wt_bits = parse_bits_axis(&s, "wt-bits")?;
    }
    if let Some(s) = psum {
        if !s.eq_ignore_ascii_case("auto") {
            req.psum_bits = parse_bits_axis(&s, "psum-bits")?;
        }
    }
    if let Some(s) = mac {
        req.mac = MacKind::parse(&s.to_ascii_lowercase()).ok_or_else(|| {
            QappaError::Config(format!("--mac: unknown datapath '{s}' (expected fp|int|light<n>)"))
        })?;
    }
    if let Some(s) = types {
        req.types = s
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(str::to_string)
            .collect();
    }
    Ok(Some(req))
}

/// `qappa explore --act-bits 4:16 --wt-bits 2:8 [...]`: precision-grid DSE
/// through the chunked sweep engine, one report row per precision cell.
fn cmd_dse_precision(
    args: &Args,
    specs: &[&str],
    precision: PrecisionRequest,
) -> Result<(), QappaError> {
    let mut named = Vec::with_capacity(specs.len());
    for spec in specs {
        let (name, layers) = workloads::load(spec)?;
        named.push(NamedWorkload::new(name, layers));
    }
    let grid = precision.resolve()?;
    let session = session_from(args)?;
    let out = args.opt("out").map(str::to_string);
    let stats_json = args.opt("stats-json").map(str::to_string);
    if args.flag("scatter") || args.flag("stats") {
        return Err(QappaError::Config(
            "--scatter/--stats are not available for precision-grid runs yet".into(),
        ));
    }
    args.finish()?;

    let t0 = std::time::Instant::now();
    let summaries = session.explore_precision(&named, &precision)?;
    let dt = t0.elapsed().as_secs_f64();

    // Wall time and chunk size go to stderr: the stdout report is
    // deterministic for a fixed seed, byte-for-byte across --chunk values.
    println!(
        "Precision-grid DSE over {} workload(s) — {} precision cells x {} configs, \
         backend=native (unified {}-feature model)",
        named.len(),
        grid.len(),
        session.options().space.len(),
        qappa::config::QUANT_NUM_FEATURES,
    );
    for s in &summaries {
        println!("anchor[{}]: {}", s.workload, s.anchor.cfg.key());
    }
    print!("{}", precision_summary_table(&summaries).render());
    // Progress/stats to stderr: piped stdout stays a parseable report.
    qappa::obs::diag(
        "store",
        format_args!(
            "models trained: {} (cache hits: {}); chunk={}, {:.2}s",
            session.store().misses(),
            session.store().hits(),
            session.options().chunk,
            dt
        ),
    );
    let (ch, cm, sh, sm) =
        memo_totals(summaries.iter().flat_map(|s| s.stats.values()));
    memo_line(ch, cm, sh, sm);
    if let Some(dir) = out {
        let path = format!("{dir}/precision_summary.csv");
        write_csv(&precision_summary_table(&summaries), &path)?;
        println!("wrote {path}");
    }
    emit_stats_json(stats_json.as_deref())?;
    Ok(())
}

/// Final memo counters of one engine run.  Per-cell `SweepStats`
/// snapshots are cumulative over the engine's lifetime, so the run total
/// is the per-counter maximum — summing would multi-count shared state.
fn memo_totals<'a>(stats: impl Iterator<Item = &'a SweepStats>) -> (u64, u64, u64, u64) {
    let mut t = (0u64, 0u64, 0u64, 0u64);
    for s in stats {
        t.0 = t.0.max(s.cost_hits);
        t.1 = t.1.max(s.cost_misses);
        t.2 = t.2.max(s.synth_hits);
        t.3 = t.3.max(s.synth_misses);
    }
    t
}

/// The `[engine]` memo stderr line shared by the explore/optimize paths.
fn memo_line(cost_hits: u64, cost_misses: u64, synth_hits: u64, synth_misses: u64) {
    qappa::obs::diag(
        "engine",
        format_args!(
            "layer-cost memo: {cost_hits} hits / {cost_misses} misses; \
             synth memo: {synth_hits} hits / {synth_misses} misses"
        ),
    );
}

fn cmd_dse(args: &Args) -> Result<(), QappaError> {
    let spec = args.require("workload")?.to_string();
    let specs: Vec<&str> = spec.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if specs.is_empty() {
        return Err(QappaError::Workload("--workload: empty workload list".into()));
    }
    if let Some(precision) = parse_precision_flags(args)? {
        return cmd_dse_precision(args, &specs, precision);
    }
    if specs.len() > 1 {
        return cmd_dse_multi(args, &specs);
    }
    let (wl, layers) = workloads::load(specs[0])?;
    let session = session_from(args)?;
    let out = args.opt("out").map(str::to_string);
    let stats_json = args.opt("stats-json").map(str::to_string);
    let want_scatter = args.flag("scatter");
    let want_stats = args.flag("stats");
    let backend_name = session.backend_name()?;
    args.finish()?;

    let t0 = std::time::Instant::now();
    let res = session.dse(&wl, &layers)?;
    let dt = t0.elapsed().as_secs_f64();

    // Wall time goes to stderr: the stdout report is deterministic for a
    // fixed seed, byte-for-byte across --chunk values.
    println!(
        "DSE over {} ({} layers) — {} configs/type, backend={}",
        wl,
        layers.len(),
        session.options().space.len(),
        backend_name,
    );
    println!("anchor (best INT16 perf/area): {}", res.anchor.cfg.key());
    print!("{}", dse_summary_table(&res).render());
    if want_stats {
        print!("{}", dse_stats_table(&res).render());
    }
    qappa::obs::diag("store", format_args!("dse wall time: {dt:.2}s"));
    let (ch, cm, sh, sm) = memo_totals(res.stats.values());
    memo_line(ch, cm, sh, sm);
    if let Some(engine) = session.engine() {
        let s = &engine.stats;
        use std::sync::atomic::Ordering::Relaxed;
        // Progress/stats to stderr: piped stdout stays a parseable report.
        qappa::obs::diag(
            "engine",
            format_args!(
                "predict: {} rows in {} batches ({} padded rows), fit: {}, loss: {}",
                s.predict_rows.load(Relaxed),
                s.predict_batches.load(Relaxed),
                s.predict_padded_rows.load(Relaxed),
                s.fit_calls.load(Relaxed),
                s.loss_calls.load(Relaxed)
            ),
        );
    }
    if let Some(dir) = out {
        let stem = sanitize_name(&wl);
        let summary_path = format!("{dir}/{stem}_summary.csv");
        write_csv(&dse_summary_table(&res), &summary_path)?;
        println!("wrote {summary_path}");
        if want_scatter {
            let scatter_path = format!("{dir}/{stem}_scatter.csv");
            write_csv(&dse_scatter_table(&res), &scatter_path)?;
            println!("wrote {scatter_path}");
        }
    }
    emit_stats_json(stats_json.as_deref())?;
    Ok(())
}

/// `qappa explore --workload a,b,c`: one streaming pass over the grid per
/// PE type, every workload evaluated against each predicted shard; models
/// trained once and shared through the session's `ModelStore`.
fn cmd_dse_multi(args: &Args, specs: &[&str]) -> Result<(), QappaError> {
    let mut named = Vec::with_capacity(specs.len());
    for spec in specs {
        let (name, layers) = workloads::load(spec)?;
        named.push(NamedWorkload::new(name, layers));
    }
    let session = session_from(args)?;
    let out = args.opt("out").map(str::to_string);
    let stats_json = args.opt("stats-json").map(str::to_string);
    let want_stats = args.flag("stats");
    if args.flag("scatter") {
        return Err(QappaError::Config(
            "--scatter needs the full point set; it is only available for \
             single-workload runs"
                .into(),
        ));
    }
    let backend_name = session.backend_name()?;
    args.finish()?;

    let t0 = std::time::Instant::now();
    let summaries = session.explore_named(&named)?;
    let dt = t0.elapsed().as_secs_f64();

    // Wall time and chunk size go to stderr: the stdout report is
    // deterministic for a fixed seed, byte-for-byte across --chunk values.
    println!(
        "DSE over {} workloads ({}) — {} configs/type, top-k={}, backend={}",
        named.len(),
        named.iter().map(|w| w.name.as_str()).collect::<Vec<_>>().join(", "),
        session.options().space.len(),
        session.options().topk,
        backend_name,
    );
    for s in &summaries {
        println!(
            "anchor[{}] (best INT16 perf/area): {}",
            s.workload,
            s.anchor.cfg.key()
        );
    }
    print!("{}", multi_summary_table(&summaries).render());
    // Progress/stats to stderr: piped stdout stays a parseable report.
    qappa::obs::diag(
        "store",
        format_args!(
            "models trained: {} (cache hits: {}); chunk={}, {:.2}s",
            session.store().misses(),
            session.store().hits(),
            session.options().chunk,
            dt
        ),
    );
    let peak = summaries
        .iter()
        .flat_map(|s| s.stats.values().map(|st| st.peak_resident))
        .max()
        .unwrap_or(0);
    qappa::obs::diag(
        "engine",
        format_args!(
            "peak resident points: {} of {} evaluated per (type, workload)",
            peak,
            session.options().space.len()
        ),
    );
    let (ch, cm, sh, sm) =
        memo_totals(summaries.iter().flat_map(|s| s.stats.values()));
    memo_line(ch, cm, sh, sm);
    if want_stats {
        print!("{}", sweep_stats_table(&summaries).render());
    }
    if let Some(dir) = out {
        let path = format!("{dir}/multi_summary.csv");
        write_csv(&multi_summary_table(&summaries), &path)?;
        println!("wrote {path}");
    }
    emit_stats_json(stats_json.as_deref())?;
    Ok(())
}

/// Optional typed flag: absent -> `None`, present-but-unparseable -> error
/// naming the flag.
fn flag_opt<T: std::str::FromStr>(args: &Args, name: &str) -> Result<Option<T>, QappaError> {
    match args.opt(name) {
        None => Ok(None),
        Some(s) => s
            .parse::<T>()
            .map(Some)
            .map_err(|_| QappaError::Config(format!("--{name}: cannot parse '{s}'"))),
    }
}

/// Comma-separated multiplier list (`--width-mults 1.0,0.75`); absent ->
/// empty (no model knob on that axis).
fn parse_mults(args: &Args, name: &str) -> Result<Vec<f64>, QappaError> {
    match args.opt(name) {
        None => Ok(Vec::new()),
        Some(s) => s
            .split(',')
            .map(str::trim)
            .filter(|v| !v.is_empty())
            .map(|v| {
                v.parse::<f64>()
                    .map_err(|_| QappaError::Config(format!("--{name}: cannot parse '{v}'")))
            })
            .collect(),
    }
}

/// `qappa optimize`: guided multi-objective search over hardware x model
/// knobs x per-layer precision (docs/OPTIMIZER.md).  Thin client of
/// [`Qappa::optimize`] — the CLI, the serve loop and library callers all
/// produce identical frontiers for identical seeds.
fn cmd_optimize(args: &Args) -> Result<(), QappaError> {
    let workload = args.require("workload")?.to_string();
    let objectives: Vec<String> = args
        .opt("objectives")
        .map(|s| {
            s.split(',')
                .map(str::trim)
                .filter(|o| !o.is_empty())
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();
    let precision = parse_precision_flags(args)?;
    // Measured sensitivity table: parse here so a bad path or malformed
    // JSON errors before any session spins up; schema checks (unknown
    // fields, layer coverage) stay in the session/accuracy layer.
    let sensitivity = match args.opt("sensitivity") {
        None => None,
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| QappaError::io(format!("reading {path}"), e))?;
            Some(qappa::util::json::Json::parse(&text).map_err(|e| {
                QappaError::Config(format!("--sensitivity {path}: {e}"))
            })?)
        }
    };
    let req = OptimizeRequest {
        workload,
        objectives,
        constraints: Constraints {
            max_area_mm2: flag_opt(args, "max-area-mm2")?,
            max_power_mw: flag_opt(args, "max-power-mw")?,
            max_latency_ms: flag_opt(args, "max-latency-ms")?,
            min_bits: flag_opt(args, "min-bits")?,
            min_accuracy: flag_opt(args, "min-accuracy")?,
        },
        sensitivity,
        width_mults: parse_mults(args, "width-mults")?,
        depth_mults: parse_mults(args, "depth-mults")?,
        strategy: args.opt("strategy").map(str::to_string),
        budget: flag_opt(args, "budget")?,
        pop: flag_opt(args, "pop")?,
        // --seed already feeds the session recipe; the request falls back
        // to the session seed, so one flag drives both.
        seed: None,
        per_layer: if args.flag("uniform") { Some(false) } else { None },
        precision,
        phase: args.opt("phase").map(str::to_string),
        ctx: flag_opt(args, "ctx")?,
    };
    let session = session_from(args)?;
    let out = args.opt("out").map(str::to_string);
    let stats_json = args.opt("stats-json").map(str::to_string);
    args.finish()?;

    let t0 = std::time::Instant::now();
    let resp = session.optimize(&req)?;
    let dt = t0.elapsed().as_secs_f64();

    println!(
        "Guided optimize over {} — strategy={}, objectives=[{}], {} evaluations (budget {})",
        resp.workload,
        resp.strategy,
        resp.objectives.join(", "),
        resp.evaluated,
        resp.budget
    );
    println!(
        "frontier: {} points, hypervolume {:.6e} (ref [{}])",
        resp.frontier.len(),
        resp.hypervolume,
        resp.ref_point.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(", ")
    );
    print!("{}", opt_frontier_table(&resp).render());
    println!("convergence:");
    print!("{}", opt_convergence_table(&resp).render());
    // Progress/stats to stderr: piped stdout stays a parseable report.
    qappa::obs::diag(
        "store",
        format_args!(
            "models trained: {} (cache hits: {}); {:.2}s",
            session.store().misses(),
            session.store().hits(),
            dt
        ),
    );
    memo_line(
        resp.memo.cost_hits,
        resp.memo.cost_misses,
        resp.memo.synth_hits,
        resp.memo.synth_misses,
    );
    if let Some(dir) = out {
        let frontier_path = format!("{dir}/optimize_frontier.csv");
        write_csv(&opt_frontier_table(&resp), &frontier_path)?;
        println!("wrote {frontier_path}");
        let conv_path = format!("{dir}/optimize_convergence.csv");
        write_csv(&opt_convergence_table(&resp), &conv_path)?;
        println!("wrote {conv_path}");
    }
    emit_stats_json(stats_json.as_deref())?;
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<(), QappaError> {
    let out = args.opt("out").unwrap_or("figures").to_string();
    let session = session_from(args)?;
    let _all = args.flag("all");
    session.backend_name()?;
    args.finish()?;

    // Fig 2.
    let rows = session.accuracy(128)?;
    let t2 = fig2_table(&rows);
    println!("Figure 2 — model accuracy");
    print!("{}", t2.render());
    write_csv(&t2, &format!("{out}/fig2_accuracy.csv"))?;

    // Figs 3-5.
    for (fig, wl) in [(3, "vgg16"), (4, "resnet34"), (5, "resnet50")] {
        let layers = workloads::by_name(wl).unwrap();
        let res = session.dse(wl, &layers)?;
        println!("\nFigure {fig} — {wl} design space (anchor {})", res.anchor.cfg.key());
        let ts = dse_summary_table(&res);
        print!("{}", ts.render());
        write_csv(&ts, &format!("{out}/fig{fig}_{wl}_summary.csv"))?;
        write_csv(&dse_scatter_table(&res), &format!("{out}/fig{fig}_{wl}_scatter.csv"))?;
    }
    println!("\nwrote CSVs under {out}/");
    Ok(())
}

fn cmd_rtl(args: &Args) -> Result<(), QappaError> {
    let cfg = parse_config(args)?;
    let out = args.opt("out").map(str::to_string);
    args.finish()?;
    let v = qappa::rtl::verilog::generate(&cfg);
    match out {
        Some(path) => {
            std::fs::write(&path, &v).map_err(|e| QappaError::io(format!("writing {path}"), e))?;
            println!("wrote {} ({} bytes)", path, v.len());
        }
        None => print!("{v}"),
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<(), QappaError> {
    let n = args.get("vectors", 500usize)?;
    args.finish()?;
    println!("gate-level verification ({n} random vectors each):");
    let act = qappa::rtl::sim::verify_int16_multiplier(n, 0xc0ffee)?;
    println!("  int16 multiplier  OK   (activity {:.3})", act);
    for w in [20u32, 24] {
        let act = qappa::rtl::sim::verify_light_term(w, n, 0xbeef)?;
        println!("  light term w={w}    OK   (activity {:.3})", act);
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<(), QappaError> {
    let spec = args.require("workload")?.to_string();
    let cfg = parse_config(args)?;
    let phase = args.opt("phase").map(str::to_string);
    let ctx = flag_opt(args, "ctx")?;
    let accuracy = args.flag("accuracy").then_some(true);
    args.finish()?;

    let session = Qappa::builder().build();
    let resp =
        session.analyze(&AnalyzeRequest { workload: spec, config: cfg, phase, ctx, accuracy })?;
    println!(
        "config: {}  ({:.2} mW, {:.0} MHz, {:.3} mm2)",
        resp.config.key(),
        resp.ppa.power_mw,
        resp.ppa.fmax_mhz,
        resp.ppa.area_mm2
    );
    // Mixed-precision workloads get a precision column, phased/transformer
    // runs arithmetic-intensity and KV columns; plain runs keep the
    // historical table byte-for-byte.
    let mixed = resp.layers.iter().any(|l| l.precision.is_some());
    let phased = resp.phase.is_some() || resp.layers.iter().any(|l| l.kv_bytes.is_some());
    let mut header = vec![
        "layer", "MACs_M", "cycles_k", "util", "stall_%", "dram_MB",
        "energy_mJ", "E_compute", "E_dram", "E_other",
    ];
    if phased {
        header.push("AI");
        header.push("KV_MB");
    }
    if mixed {
        header.push("precision");
    }
    let mut t = Table::new(&header);
    for l in &resp.layers {
        let mut row = vec![
            l.name.clone(),
            format!("{:.1}", l.macs as f64 / 1e6),
            format!("{:.0}", l.cycles as f64 / 1e3),
            format!("{:.2}", l.utilization),
            format!("{:.0}", 100.0 * l.stall_cycles as f64 / l.cycles.max(1) as f64),
            format!("{:.2}", l.dram_bytes as f64 / 1e6),
            format!("{:.3}", l.total_mj),
            format!("{:.3}", l.compute_mj),
            format!("{:.3}", l.dram_mj),
            format!("{:.3}", l.other_mj),
        ];
        if phased {
            row.push(format!("{:.2}", l.macs as f64 / l.dram_bytes.max(1) as f64));
            row.push(match l.kv_bytes {
                Some(kv) => format!("{:.2}", kv as f64 / 1e6),
                None => "-".to_string(),
            });
        }
        if mixed {
            row.push(l.precision.clone().unwrap_or_else(|| "-".to_string()));
        }
        t.row(row);
    }
    print!("{}", t.render());
    println!(
        "total: {:.2} ms/inference ({:.1} inf/s), {:.2} mJ/inference",
        resp.latency_s * 1e3,
        1.0 / resp.latency_s,
        resp.energy_mj
    );
    if phased {
        let macs: u64 = resp.layers.iter().map(|l| l.macs).sum();
        let dram: u64 = resp.layers.iter().map(|l| l.dram_bytes).sum();
        let kv: u64 = resp.layers.iter().map(|l| l.kv_bytes.unwrap_or(0)).sum();
        println!(
            "arithmetic intensity: {:.2} MACs/DRAM-byte; KV-cache traffic: {:.2} MB",
            macs as f64 / dram.max(1) as f64,
            kv as f64 / 1e6
        );
    }
    if let Some(p) = &resp.phase {
        println!(
            "phase {} @ ctx {}: prefill {:.2} ms / {:.2} mJ; decode {:.3} ms/tok / \
             {:.3} mJ/tok (KV {:.2} MB/tok)",
            p.phase,
            p.ctx,
            p.prefill_latency_s * 1e3,
            p.prefill_energy_mj,
            p.decode_latency_s * 1e3,
            p.decode_energy_mj,
            p.kv_dram_bytes as f64 / 1e6
        );
        println!(
            "phase total ({}): {:.2} ms, {:.2} mJ",
            p.phase,
            p.total_latency_s * 1e3,
            p.total_energy_mj
        );
    }
    if let Some(a) = resp.accuracy {
        println!("estimated accuracy: {:.4} of the fp32 baseline (docs/ACCURACY.md)", a);
    }
    Ok(())
}

fn cmd_workloads(args: &Args) -> Result<(), QappaError> {
    let detail = args.opt("workload").map(str::to_string);
    args.finish()?;
    let session = Qappa::builder().build();
    match session.workloads(&WorkloadsRequest { workload: detail })? {
        WorkloadsResponse::Detail { name, layers } => {
            let macs: u64 = layers.iter().map(|l| l.macs()).sum();
            println!("{name}: {} layers, {:.2} GMACs", layers.len(), macs as f64 / 1e9);
            print!("{}", workload_table(&layers).render());
        }
        WorkloadsResponse::List(infos) => {
            for i in &infos {
                println!(
                    "{}: {} layers ({} depthwise), {:.2} GMACs",
                    i.name,
                    i.layers,
                    i.depthwise,
                    i.macs as f64 / 1e9
                );
            }
            println!("\n(`workloads --workload W` prints the per-layer table)");
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), QappaError> {
    if let Some(listen) = args.opt("listen").map(str::to_string) {
        return cmd_serve_listen(args, &listen);
    }
    let session = session_from(args)?;
    let opts = ServeOptions {
        concurrency: args.get("concurrency", ServeOptions::default().concurrency)?,
    };
    args.finish()?;
    qappa::obs::diag(
        "qappa",
        format_args!(
            "serving JSON-lines requests on stdin (concurrency {}); \
             protocol: docs/API.md",
            opts.concurrency.max(1)
        ),
    );
    let stats = qappa::api::serve(&session, std::io::stdin().lock(), std::io::stdout(), &opts)?;
    qappa::obs::diag(
        "qappa",
        format_args!(
            "served {} requests ({} ok, {} errors); models trained: {} (cache hits: {})",
            stats.requests,
            stats.ok,
            stats.errors,
            session.store().misses(),
            session.store().hits()
        ),
    );
    Ok(())
}

/// `qappa serve --listen HOST:PORT`: the concurrent TCP endpoint.  Blocks
/// until EOF on stdin (Ctrl-D, or the spawning harness closing the pipe),
/// then drains gracefully — in-flight requests complete and flush before
/// the process exits (docs/SERVE.md).
fn cmd_serve_listen(args: &Args, listen: &str) -> Result<(), QappaError> {
    let td = TransportOptions::default();
    let session = Arc::new(builder_from(args)?.store(process_store()).build());
    let opts = TransportOptions {
        max_connections: args.get("max-connections", td.max_connections)?,
        concurrency: args.get("concurrency", td.concurrency)?,
        max_line_bytes: args.get("max-line-bytes", td.max_line_bytes)?,
        dispatch: DispatchOptions {
            max_inflight: args.get("max-inflight", td.dispatch.max_inflight)?,
            coalesce: !args.flag("no-coalesce"),
        },
    };
    args.finish()?;
    let mut server = TcpServer::bind(session.clone(), listen, opts)?;
    qappa::obs::diag(
        "qappa",
        format_args!(
            "serving JSON-lines over TCP on {} (max {} connections, {} in flight, \
             coalescing {}); EOF on stdin drains and exits — docs/SERVE.md",
            server.local_addr(),
            opts.max_connections,
            opts.dispatch.max_inflight,
            if opts.dispatch.coalesce { "on" } else { "off" }
        ),
    );
    // Park until the operator (or spawning harness) closes stdin.
    let _ = std::io::copy(&mut std::io::stdin().lock(), &mut std::io::sink());
    server.shutdown();
    let st = server.stats();
    qappa::obs::diag(
        "qappa",
        format_args!(
            "served {} connections ({} shed), {} requests ({} ok, {} errors, \
             {} shed, {} coalesced, {} cancelled); models trained: {} (cache hits: {})",
            st.connections,
            st.shed_connections,
            st.dispatch.requests,
            st.dispatch.ok,
            st.dispatch.errors,
            st.dispatch.shed,
            st.dispatch.coalesced,
            st.dispatch.cancelled,
            session.store().misses(),
            session.store().hits()
        ),
    );
    Ok(())
}

/// `qappa loadgen`: N lockstep connections x M requests against a serve
/// endpoint; stdout is exactly one JSON report line (everything else goes
/// to stderr), and a run with request errors exits nonzero.
fn cmd_loadgen(args: &Args) -> Result<(), QappaError> {
    let ld = LoadgenOptions::default();
    let opts = LoadgenOptions {
        connections: args.get("connections", ld.connections)?,
        requests: args.get("requests", ld.requests)?,
        mix: RequestMix::parse(args.opt("mix").unwrap_or("explore"))?,
        warmup: !args.flag("cold"),
        connect_timeout_ms: args.get("connect-timeout-ms", ld.connect_timeout_ms)?,
    };
    let report = match args.opt("addr").map(str::to_string) {
        Some(addr) => {
            args.finish()?;
            run_loadgen(&addr, &opts)?
        }
        None => {
            // No --addr: spin an in-process server on an ephemeral port so
            // `qappa loadgen` works standalone (session flags apply).
            let session = Arc::new(builder_from(args)?.store(process_store()).build());
            args.finish()?;
            let mut server =
                TcpServer::bind(session, "127.0.0.1:0", TransportOptions::default())?;
            let report = run_loadgen(&server.local_addr().to_string(), &opts)?;
            server.shutdown();
            report
        }
    };
    println!("{}", report.to_json());
    qappa::obs::diag(
        "qappa",
        format_args!(
            "loadgen: {} connections x {} requests ({}), {:.1} req/s, \
             p50 {:.2} ms, p99 {:.2} ms",
            report.connections,
            opts.requests,
            opts.mix.label(),
            report.throughput_per_s,
            report.p50_ms,
            report.p99_ms
        ),
    );
    if report.errors > 0 {
        return Err(QappaError::Protocol(format!(
            "loadgen: {} of {} requests failed",
            report.errors, report.requests
        )));
    }
    Ok(())
}

/// `qappa metrics`: print one JSON snapshot of the metrics registry on
/// stdout.  With `--addr` the snapshot comes from a live serve endpoint
/// via the `metrics` wire op; without it, from this (freshly started)
/// process — mainly useful for scripting against a server.
fn cmd_metrics(args: &Args) -> Result<(), QappaError> {
    let addr = args.opt("addr").map(str::to_string);
    args.finish()?;
    let snap = match addr {
        Some(addr) => {
            use std::io::{BufRead, BufReader, Write};
            let mut stream = std::net::TcpStream::connect(&addr)
                .map_err(|e| QappaError::io(format!("connecting to {addr}"), e))?;
            writeln!(stream, "{{\"id\":1,\"op\":\"metrics\"}}")
                .and_then(|_| stream.flush())
                .map_err(|e| QappaError::io("writing metrics request", e))?;
            let mut line = String::new();
            BufReader::new(stream)
                .read_line(&mut line)
                .map_err(|e| QappaError::io("reading metrics response", e))?;
            let resp = ServeResponse::from_json(&qappa::util::json::Json::parse(&line)?)?;
            match resp.result {
                Ok(ResponseBody::Metrics(snap)) => snap,
                Ok(other) => {
                    return Err(QappaError::Protocol(format!(
                        "metrics: unexpected '{}' response",
                        other.op()
                    )))
                }
                Err(e) => {
                    return Err(QappaError::Protocol(format!(
                        "metrics: server answered {}: {}",
                        e.kind, e.message
                    )))
                }
            }
        }
        None => qappa::obs::registry().snapshot(),
    };
    println!("{snap}", snap = snap.to_json());
    Ok(())
}
