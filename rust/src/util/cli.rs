//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `qappa <subcommand> [--key value]... [--flag]... [positional]...`
//! Unknown options are an error; every accessor records the keys it was
//! asked for so `finish()` can reject typos.

use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: BTreeSet<String>,
    pub positional: Vec<String>,
    consumed: std::cell::RefCell<BTreeSet<String>>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw arguments (without argv[0]). `boolean_flags` lists options
    /// that take no value.
    pub fn parse(raw: &[String], boolean_flags: &[&str]) -> Result<Args, CliError> {
        let boolset: BTreeSet<&str> = boolean_flags.iter().copied().collect();
        let mut args = Args {
            subcommand: None,
            opts: BTreeMap::new(),
            flags: BTreeSet::new(),
            positional: Vec::new(),
            consumed: Default::default(),
        };
        let mut it = raw.iter().peekable();
        // first non-option token is the subcommand
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                    continue;
                }
                if boolset.contains(name) {
                    args.flags.insert(name.to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| CliError(format!("--{name} requires a value")))?;
                    args.opts.insert(name.to_string(), val.clone());
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn from_env(boolean_flags: &[&str]) -> Result<Args, CliError> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, boolean_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().insert(name.to_string());
        self.flags.contains(name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(name.to_string());
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|_| CliError(format!("--{name}: cannot parse '{s}'"))),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str, CliError> {
        self.opt(name)
            .ok_or_else(|| CliError(format!("--{name} is required")))
    }

    /// Error on any option that was provided but never consumed.
    pub fn finish(&self) -> Result<(), CliError> {
        let consumed = self.consumed.borrow();
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !consumed.contains(k) {
                return Err(CliError(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(&v(&["dse", "--workload", "vgg16", "--verbose", "out.csv"]),
                            &["verbose"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("dse"));
        assert_eq!(a.opt("workload"), Some("vgg16"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.csv"]);
        a.finish().unwrap();
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&v(&["fit", "--k=5"]), &[]).unwrap();
        assert_eq!(a.get::<usize>("k", 0).unwrap(), 5);
        a.finish().unwrap();
    }

    #[test]
    fn typed_defaults_and_errors() {
        let a = Args::parse(&v(&["x", "--n", "12"]), &[]).unwrap();
        assert_eq!(a.get::<u32>("n", 0).unwrap(), 12);
        assert_eq!(a.get::<u32>("missing", 7).unwrap(), 7);
        let b = Args::parse(&v(&["x", "--n", "abc"]), &[]).unwrap();
        assert!(b.get::<u32>("n", 0).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&v(&["x", "--k"]), &[]).is_err());
    }

    #[test]
    fn unknown_option_rejected_by_finish() {
        let a = Args::parse(&v(&["x", "--oops", "1"]), &[]).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn no_subcommand_when_first_is_option() {
        let a = Args::parse(&v(&["--k", "1"]), &[]).unwrap();
        assert_eq!(a.subcommand, None);
        assert_eq!(a.opt("k"), Some("1"));
    }

    #[test]
    fn require_reports_missing() {
        let a = Args::parse(&v(&["x"]), &[]).unwrap();
        assert!(a.require("pe-type").is_err());
    }
}
