//! Small statistics toolkit: summary stats for benches, fit-quality metrics
//! (R², MAPE, Pearson r) for the Figure-2 model-accuracy reproduction.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// p-th percentile (0..=100) with linear interpolation; panics on empty.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Coefficient of determination of predictions vs actuals.
pub fn r2(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    let m = mean(actual);
    let ss_tot: f64 = actual.iter().map(|y| (y - m) * (y - m)).sum();
    let ss_res: f64 = actual
        .iter()
        .zip(predicted)
        .map(|(y, yh)| (y - yh) * (y - yh))
        .sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            f64::NEG_INFINITY
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Mean absolute percentage error (%); rows with |actual| < eps are skipped.
pub fn mape(actual: &[f64], predicted: &[f64]) -> f64 {
    assert_eq!(actual.len(), predicted.len());
    let eps = 1e-12;
    let mut total = 0.0;
    let mut n = 0usize;
    for (y, yh) in actual.iter().zip(predicted) {
        if y.abs() > eps {
            total += ((y - yh) / y).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * total / n as f64
    }
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx * dy).sqrt()
    }
}

/// Geometric mean (inputs must be positive).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Timing summary used by the bench harness.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty slice");
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((variance(&[1.0, 2.0, 3.0]) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn r2_perfect_and_mean() {
        let y = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(r2(&y, &y), 1.0);
        let mean_pred = [2.5; 4];
        assert!(r2(&y, &mean_pred).abs() < 1e-12);
    }

    #[test]
    fn mape_basic() {
        let y = [100.0, 200.0];
        let yh = [110.0, 180.0];
        assert!((mape(&y, &yh) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_signs() {
        let x = [1.0, 2.0, 3.0];
        assert!((pearson(&x, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-12);
        assert!((pearson(&x, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.p50, 2.5);
    }
}
