//! Plain-text table rendering + CSV writing for figure reports.

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(c);
                for _ in c.chars().count()..width[i] {
                    out.push(' ');
                }
            }
            // trim trailing spaces
            while out.ends_with(' ') {
                out.pop();
            }
            out.push('\n');
        };
        fmt_row(&self.header, &mut out);
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }

    /// CSV serialization (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        out.push_str(
            &self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format a float with engineering-ish precision for reports.
pub fn fmt_g(x: f64) -> String {
    if x == 0.0 {
        return "0".to_string();
    }
    let a = x.abs();
    if a >= 1e5 || a < 1e-3 {
        format!("{:.3e}", x)
    } else if a >= 100.0 {
        format!("{:.1}", x)
    } else if a >= 1.0 {
        format!("{:.3}", x)
    } else {
        format!("{:.4}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn wrong_width_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new(&["k", "v"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    fn fmt_g_ranges() {
        assert_eq!(fmt_g(0.0), "0");
        assert_eq!(fmt_g(3.0), "3.000");
        assert_eq!(fmt_g(312.5), "312.5");
        assert!(fmt_g(1.23e7).contains('e'));
        assert!(fmt_g(0.00012).contains('e'));
    }
}
