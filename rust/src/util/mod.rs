//! Infrastructure substrates built in-repo (the offline environment has no
//! serde / clap / rayon / criterion — see DESIGN.md §2 substitutions).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prng;
pub mod queue;
pub mod stats;
pub mod table;
