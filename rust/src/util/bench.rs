//! Criterion-style micro-bench harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this directly:
//! warmup, fixed sample count, median/p95 reporting, and a throughput
//! helper.  Output format is stable so `bench_output.txt` diffs cleanly.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Summary;

/// One benchmark run.
pub struct Bench {
    pub name: String,
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

/// Result of a bench (seconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// Optional units-per-iteration for throughput reporting.
    pub units: Option<(f64, &'static str)>,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        Bench { name: name.to_string(), warmup_iters: 2, sample_iters: 10 }
    }

    pub fn warmup(mut self, n: usize) -> Bench {
        self.warmup_iters = n;
        self
    }

    pub fn samples(mut self, n: usize) -> Bench {
        self.sample_iters = n;
        self
    }

    /// Time `f`; per-iteration wall time is recorded.
    pub fn run<R>(&self, mut f: impl FnMut() -> R) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: self.name.clone(),
            summary: Summary::of(&times),
            units: None,
        }
    }

    /// Like `run`, with a throughput annotation (`units` processed per
    /// iteration, e.g. configs, rows, layers).
    pub fn run_with_units<R>(
        &self,
        units: f64,
        unit_name: &'static str,
        f: impl FnMut() -> R,
    ) -> BenchResult {
        let mut r = self.run(f);
        r.units = Some((units, unit_name));
        r
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

impl BenchResult {
    /// Render one criterion-ish report line (plus throughput if units set).
    pub fn report(&self) -> String {
        let s = &self.summary;
        let mut line = format!(
            "{:<42} time: [{} {} {}]",
            self.name,
            fmt_time(s.min),
            fmt_time(s.p50),
            fmt_time(s.p95),
        );
        if let Some((units, name)) = self.units {
            let thrpt = units / s.p50;
            line.push_str(&format!("  thrpt: {:.1} {}/s", thrpt, name));
        }
        line
    }

    pub fn print(&self) -> &Self {
        println!("{}", self.report());
        self
    }

    /// Machine-readable form for measurement mode (`tools/bench.sh`).
    pub fn to_json(&self) -> Json {
        let s = &self.summary;
        let mut pairs = vec![
            ("name".to_string(), Json::Str(self.name.clone())),
            ("samples".to_string(), Json::Num(s.n as f64)),
            ("min_s".to_string(), Json::Num(s.min)),
            ("p50_s".to_string(), Json::Num(s.p50)),
            ("p95_s".to_string(), Json::Num(s.p95)),
            ("mean_s".to_string(), Json::Num(s.mean)),
        ];
        if let Some((units, unit_name)) = self.units {
            pairs.push(("units".to_string(), Json::Num(units)));
            pairs.push(("unit".to_string(), Json::Str(unit_name.to_string())));
            pairs.push(("throughput_per_s".to_string(), Json::Num(units / s.p50)));
        }
        Json::Obj(pairs.into_iter().collect())
    }
}

/// Measurement-mode collector: benches push their [`BenchResult`]s (plus
/// free-form scalar metrics like hypervolume-vs-budget) and, when the
/// `QAPPA_BENCH_JSON` environment variable names a path, one JSON document
/// is written there — the machine-readable perf trajectory `tools/bench.sh`
/// emits and CI uploads as an artifact.
#[derive(Default)]
pub struct BenchReport {
    results: Vec<BenchResult>,
    metrics: Vec<(String, f64)>,
}

impl BenchReport {
    pub fn new() -> BenchReport {
        BenchReport::default()
    }

    pub fn push(&mut self, r: &BenchResult) {
        self.results.push(r.clone());
    }

    /// Record a free-form scalar (e.g. `hypervolume/nsga2/budget=1000`).
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    pub fn to_json(&self) -> Json {
        let results = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
        let metrics = Json::Obj(
            self.metrics
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        Json::Obj(
            [
                ("results".to_string(), results),
                ("metrics".to_string(), metrics),
            ]
            .into_iter()
            .collect(),
        )
    }

    /// Write the JSON document to `$QAPPA_BENCH_JSON` if set (no-op
    /// otherwise), returning the path written.
    pub fn write_if_requested(&self) -> std::io::Result<Option<String>> {
        match std::env::var_os("QAPPA_BENCH_JSON") {
            None => Ok(None),
            Some(path) => {
                let path = path.to_string_lossy().to_string();
                std::fs::write(&path, format!("{}\n", self.to_json()))?;
                Ok(Some(path))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let r = Bench::new("spin").warmup(1).samples(5).run(|| {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.summary.p50 > 0.0);
        assert_eq!(r.summary.n, 5);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn throughput_annotation() {
        let r = Bench::new("units")
            .warmup(0)
            .samples(3)
            .run_with_units(100.0, "items", || std::thread::sleep(std::time::Duration::from_micros(50)));
        assert!(r.report().contains("items/s"));
    }

    #[test]
    fn bench_report_collects_results_and_metrics_as_json() {
        let r = Bench::new("unitful")
            .warmup(0)
            .samples(3)
            .run_with_units(50.0, "evals", || std::hint::black_box(1 + 1));
        let mut report = BenchReport::new();
        report.push(&r);
        report.push(&Bench::new("plain").warmup(0).samples(2).run(|| ()));
        report.metric("hypervolume/nsga2/budget=100", 1.25);
        let j = report.to_json();
        let results = j.get("results").as_arr().expect("results array");
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").as_str(), Some("unitful"));
        assert_eq!(results[0].get("unit").as_str(), Some("evals"));
        assert!(results[0].get("throughput_per_s").as_f64().unwrap() > 0.0);
        assert_eq!(results[0].get("samples").as_f64(), Some(3.0));
        // plain results omit the throughput fields
        assert!(results[1].get("unit").as_str().is_none());
        assert_eq!(
            j.get("metrics").get("hypervolume/nsga2/budget=100").as_f64(),
            Some(1.25)
        );
        // the document round-trips through the JSON writer/parser
        let text = j.to_string();
        let back = Json::parse(&text).expect("parse bench json");
        assert_eq!(back.get("results").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
