//! Minimal JSON parser/writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`,
//! `artifacts/golden.json` and the CSV/JSON reports this crate writes:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//! Numbers are kept as `f64` — all our payloads are f32-precision or small
//! integers, so this is lossless for every field we read.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---------------------------------------------------------------- parse

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; returns `Json::Null` for missing keys so lookups
    /// can be chained.
    pub fn get(&self, key: &str) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup with the same chaining convention as [`get`].
    pub fn idx(&self, i: usize) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Flatten a numeric array (any nesting is an error).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()?);
        }
        Some(out)
    }

    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        Some(self.as_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    // -------------------------------------------------------------- writers

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serialization: `json.to_string()` (via `ToString`) emits compact JSON
/// text that [`Json::parse`] round-trips.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Convenience constructor for object literals in report writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.b.len() {
                            return Err(self.err("truncated \\u escape"));
                        }
                        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                            .map_err(|_| self.err("bad \\u escape"))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        // Surrogate pairs are not needed by our payloads;
                        // map unpaired surrogates to U+FFFD.
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("a").idx(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
    }

    #[test]
    fn missing_keys_chain_to_null() {
        let v = Json::parse(r#"{"a":1}"#).unwrap();
        assert_eq!(v.get("zzz").get("deep").idx(3), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2.5,-3],"b":{"c":"q\"uote","d":true},"e":null}"#,
            r#"[[],{},"",0]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(Json::parse(&s).unwrap(), v, "case {c}");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""Aµ≤""#).unwrap();
        assert_eq!(v.as_str(), Some("Aµ≤"));
        let round = v.to_string();
        assert_eq!(Json::parse(&round).unwrap(), v);
    }

    #[test]
    fn f32_vec_helper() {
        let v = Json::parse("[1, 2.5, 3]").unwrap();
        assert_eq!(v.as_f32_vec(), Some(vec![1.0, 2.5, 3.0]));
        let bad = Json::parse("[1, \"x\"]").unwrap();
        assert_eq!(bad.as_f32_vec(), None);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }
}
