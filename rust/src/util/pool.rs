//! Scoped data-parallel map over OS threads (rayon/tokio are unavailable
//! offline; the workloads here — synthesis-oracle sweeps, dataflow
//! evaluation over tens of thousands of configs — are embarrassingly
//! parallel and CPU-bound, so `std::thread::scope` with work chunks is all
//! the coordinator needs).

/// Number of worker threads to use.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Worker count for a batch of `items`: capped so each worker gets at
/// least `min_per_worker` items (spawning a thread for a handful of cheap
/// evaluations costs more than it saves — small streaming shards hit this).
pub fn workers_for(items: usize, workers: usize, min_per_worker: usize) -> usize {
    if items == 0 {
        return 1;
    }
    workers.max(1).min(items.div_ceil(min_per_worker.max(1)))
}

/// Parallel map preserving input order.
///
/// Splits `items` into `workers` contiguous chunks; each worker writes its
/// results into a disjoint region of the output, so no locking is needed on
/// the hot path.
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(workers);
    // Split the output into disjoint &mut chunks, one per worker.
    let mut slots: Vec<&mut [Option<R>]> = out.chunks_mut(chunk).collect();
    let f_ref = &f;
    std::thread::scope(|scope| {
        for (w, slot) in slots.drain(..).enumerate() {
            let start = w * chunk;
            let input = &items[start..(start + slot.len()).min(n)];
            scope.spawn(move || {
                for (i, item) in input.iter().enumerate() {
                    slot[i] = Some(f_ref(item));
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker filled slot")).collect()
}

/// Parallel map with a per-worker context factory (e.g. a forked RNG).
pub fn parallel_map_with<T, R, C, F, Init>(
    items: &[T],
    workers: usize,
    init: Init,
    f: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&mut C, &T) -> R + Sync,
    Init: Fn(usize) -> C + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(workers);
    let mut slots: Vec<&mut [Option<R>]> = out.chunks_mut(chunk).collect();
    let f_ref = &f;
    let init_ref = &init;
    std::thread::scope(|scope| {
        for (w, slot) in slots.drain(..).enumerate() {
            let start = w * chunk;
            let input = &items[start..(start + slot.len()).min(n)];
            scope.spawn(move || {
                let mut ctx = init_ref(w);
                for (i, item) in input.iter().enumerate() {
                    slot[i] = Some(f_ref(&mut ctx, item));
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker filled slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let counter = AtomicUsize::new(0);
        let items: Vec<u32> = (0..517).collect();
        let out = parallel_map(&items, 5, |x| {
            counter.fetch_add(1, Ordering::Relaxed);
            *x
        });
        assert_eq!(out.len(), 517);
        assert_eq!(counter.load(Ordering::Relaxed), 517);
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, 4, |x| *x).is_empty());
        assert_eq!(parallel_map(&[9u32], 4, |x| *x + 1), vec![10]);
    }

    #[test]
    fn more_workers_than_items() {
        let items = [1u32, 2, 3];
        assert_eq!(parallel_map(&items, 64, |x| *x), vec![1, 2, 3]);
    }

    #[test]
    fn workers_for_caps_small_batches() {
        assert_eq!(workers_for(0, 8, 32), 1);
        assert_eq!(workers_for(10, 8, 32), 1);
        assert_eq!(workers_for(64, 8, 32), 2);
        assert_eq!(workers_for(1000, 8, 32), 8);
        assert_eq!(workers_for(1000, 0, 32), 1);
        assert_eq!(workers_for(5, 8, 0), 5); // min_per_worker clamped to 1
    }

    #[test]
    fn with_context_gives_each_worker_its_own() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map_with(
            &items,
            4,
            |w| w * 1000, // worker id as context
            |ctx, x| {
                *ctx += 1;
                *x + (*ctx % 1) // context mutation must not corrupt results
            },
        );
        assert_eq!(out, items);
    }
}
