//! A blocking bounded MPMC queue with explicit close semantics — the
//! dispatch substrate for the serve loops (std has no bounded channel whose
//! *send* side can be woken by the receive side).
//!
//! `std::sync::mpsc::sync_channel` blocks a full `send` until a receiver
//! makes room, but if every receiver has died the sender hangs forever;
//! the old serve loop worked around that with a 1 ms `try_send`/sleep poll
//! (busy-waiting one core whenever dispatch lagged the reader).  This queue
//! replaces the poll with condvars plus a `close()` that either side may
//! call: a closed queue rejects new pushes immediately (waking any blocked
//! producer) while letting consumers drain what was already queued.
//!
//! Semantics:
//!
//! * [`BoundedQueue::push`] blocks while the queue is full; returns
//!   `Err(item)` once the queue is closed (the item is handed back so the
//!   producer can decide what to do with it).
//! * [`BoundedQueue::pop`] blocks while the queue is empty; returns `None`
//!   only when the queue is closed **and** drained — close is a shutdown
//!   signal, not a data-loss event.
//! * [`BoundedQueue::close`] is idempotent and wakes every waiter on both
//!   sides.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// See the module docs.
pub struct BoundedQueue<T> {
    cap: usize,
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

impl<T> BoundedQueue<T> {
    /// A queue holding at most `cap` items (`cap` is clamped to >= 1).
    pub fn new(cap: usize) -> BoundedQueue<T> {
        BoundedQueue {
            cap: cap.max(1),
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Blocking enqueue.  Returns `Err(item)` if the queue is (or becomes,
    /// while waiting for room) closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if inner.closed {
                return Err(item);
            }
            if inner.items.len() < self.cap {
                inner.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            inner = self.not_full.wait(inner).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Blocking dequeue.  Returns `None` only once the queue is closed and
    /// every queued item has been drained.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(item) = inner.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Close the queue: pushes fail from now on, pops drain the remainder.
    /// Wakes every blocked producer and consumer; idempotent.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        inner.closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).closed
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_within_capacity() {
        let q = BoundedQueue::new(4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 4);
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn close_drains_then_ends() {
        let q = BoundedQueue::new(8);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert_eq!(q.push(3), Err(3), "closed queue rejects pushes");
        assert_eq!(q.pop(), Some(1), "close does not drop queued items");
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_a_blocked_producer() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0u32).unwrap(); // full
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || q.push(1))
        };
        // Let the producer block on the full queue, then close from the
        // consumer side: the push must return instead of hanging (this is
        // the dead-worker abort path of the serve loop).
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(producer.join().unwrap(), Err(1));
    }

    #[test]
    fn close_wakes_a_blocked_consumer() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(1));
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || q.pop())
        };
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
    }

    #[test]
    fn producer_consumer_under_pressure() {
        let q = Arc::new(BoundedQueue::new(2));
        let n = 500u32;
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..n {
                    q.push(i).unwrap();
                }
                q.close();
            })
        };
        let mut got = Vec::new();
        while let Some(i) = q.pop() {
            got.push(i);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..n).collect::<Vec<_>>());
    }
}
