//! Deterministic PRNG (the `rand` crate is unavailable offline).
//!
//! `SplitMix64` for seeding / hashing, `Xoshiro256StarStar` as the workhorse
//! generator. Both match the published reference implementations, so streams
//! are reproducible across platforms and releases — important because the
//! synthesis oracle's "tool jitter" and all sampled design sets key off
//! these streams.

/// SplitMix64 step — also used as a cheap stable hash mixer.
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Stable 64-bit hash of a byte string (FNV-1a folded through splitmix).
pub fn hash64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    let mut s = h;
    splitmix64(&mut s)
}

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256**
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Pick a random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices k > n");
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher-Yates
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(1);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.below(7)] += 1;
        }
        for c in counts {
            let expect = n / 7;
            assert!((c as i64 - expect as i64).abs() < (expect / 10) as i64);
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut dedup = s.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn hash64_stable() {
        assert_eq!(hash64(b"qappa"), hash64(b"qappa"));
        assert_ne!(hash64(b"qappa"), hash64(b"qappb"));
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
