//! Bench: streaming sweep throughput (evaluated configs/sec) vs shard size.
//!
//! Sweeps the full paper-scale INT16 grid through the `SweepEngine` at
//! chunk sizes {256, 1k, 4k} and against the eager baseline (one
//! whole-grid shard), recording throughput and the peak resident point
//! count — the speed/memory trade the streaming refactor buys.  A second
//! section sweeps a 3x3 precision grid through the same engine with the
//! unified cross-precision model, pinning a perf baseline for the
//! quantization axes.
#[path = "common.rs"]
mod common;

use qappa::config::{MacKind, PeType, QUANT_NUM_FEATURES};
use qappa::coordinator::precision::{train_quant_model, PrecisionGrid};
use qappa::coordinator::sweep::{NamedWorkload, SweepEngine};
use qappa::coordinator::{DseOptions, ModelStore};
use qappa::dataflow::Layer;
use qappa::model::native::NativeBackend;
use qappa::util::bench::{Bench, BenchReport};

fn main() {
    let mut report = BenchReport::new();

    // Benches measure the untraced hot path, and the disabled trace path
    // must stay near-free: the sink resolves once (OnceLock), so a
    // `phase_with` probe is one atomic load with the message closure never
    // run.  Budget: well under 1 µs per probe (generous — the real cost is
    // nanoseconds; the bound only catches an accidental per-call env read
    // or eager format sneaking back in).
    assert!(
        !qappa::obs::trace::enabled(),
        "benches measure the untraced hot path; unset QAPPA_TRACE"
    );
    {
        const PROBES: u32 = 100_000;
        let t0 = std::time::Instant::now();
        for _ in 0..PROBES {
            qappa::obs::trace::phase_with(
                || -> String { unreachable!("disabled sink must not format") },
                t0,
            );
        }
        let dt = t0.elapsed();
        report.metric("trace/disabled_probe_ns", dt.as_nanos() as f64 / PROBES as f64);
        assert!(
            dt.as_secs_f64() < 0.1,
            "disabled-path tracing overhead blew up: {PROBES} probes took {dt:?}"
        );
    }

    let backend = common::AnyBackend::auto();
    let mut opts = DseOptions::default();
    opts.train_per_type = 192;
    let store = ModelStore::new();
    let model = store
        .get_or_train(backend.get(), &opts, PeType::Int16)
        .expect("train INT16 model");
    let wl = vec![NamedWorkload::new(
        "conv-stack",
        vec![
            Layer::conv("c1", 64, 64, 56, 56, 3, 1, 1),
            Layer::conv("c2", 64, 128, 28, 28, 3, 1, 1),
        ],
    )];

    println!(
        "=== sweep throughput: {} configs (INT16), backend={} ===",
        opts.space.len(),
        backend.get().name()
    );
    for chunk in [0usize, 256, 1024, 4096] {
        let mut o = opts.clone();
        o.chunk = chunk;
        let label = if chunk == 0 {
            "eager(whole-grid shard)".to_string()
        } else {
            format!("chunk={chunk}")
        };
        let mut peak = 0usize;
        // One engine across warmup + samples: the warmup pass fills the
        // sweep-wide synthesis/layer-cost memos, so the samples measure the
        // steady-state (warm) hot path — the serve-session profile.
        let engine = SweepEngine::new(backend.get(), &o);
        let r = Bench::new(&format!("sweep/{label}"))
            .warmup(1)
            .samples(5)
            .run_with_units(o.space.len() as f64, "configs", || {
                let ts = engine
                    .sweep_type(&model, PeType::Int16, &wl)
                    .expect("sweep")
                    .remove(0);
                peak = ts.stats.peak_resident;
            });
        let m = engine.memo_stats();
        let lookups = m.cost_hits + m.cost_misses;
        let hit_rate =
            if lookups > 0 { m.cost_hits as f64 / lookups as f64 } else { 0.0 };
        r.print();
        report.push(&r);
        report.metric(&format!("peak_resident/{label}"), peak as f64);
        report.metric(&format!("memo_hit_rate/{label}"), hit_rate);
        println!(
            "  peak resident points: {peak}, layer-cost memo {}/{} hits ({:.0}%)",
            m.cost_hits,
            lookups,
            100.0 * hit_rate
        );
    }

    // --- precision-grid sweep: the quantization axes' perf baseline -----
    let quant_backend = NativeBackend::new(QUANT_NUM_FEATURES);
    let grid = PrecisionGrid::from_ranges(&[4, 8, 16], &[4, 8, 16], &[], MacKind::IntExact)
        .expect("precision grid");
    let qmodel =
        train_quant_model(&quant_backend, &opts, &grid.types).expect("train unified model");
    let total = grid.len() * opts.space.len();
    println!(
        "\n=== precision-grid sweep: {} cells x {} configs = {} points \
         (unified {QUANT_NUM_FEATURES}-feature model, backend=native) ===",
        grid.len(),
        opts.space.len(),
        total
    );
    for chunk in [1024usize, 4096] {
        let mut o = opts.clone();
        o.chunk = chunk;
        // One engine serves every cell, as run_dse_precision does: the
        // synthesis/layer-cost memos stay warm across the whole grid.
        let engine = SweepEngine::new(&quant_backend, &o);
        let r = Bench::new(&format!("sweep/precision-grid/chunk={chunk}"))
            .warmup(1)
            .samples(3)
            .run_with_units(total as f64, "configs", || {
                for ty in &grid.types {
                    engine.sweep_type(&qmodel, *ty, &wl).expect("precision sweep");
                }
            });
        let m = engine.memo_stats();
        let lookups = m.cost_hits + m.cost_misses;
        let hit_rate =
            if lookups > 0 { m.cost_hits as f64 / lookups as f64 } else { 0.0 };
        r.print();
        report.push(&r);
        report.metric(&format!("memo_hit_rate/precision-grid/chunk={chunk}"), hit_rate);
        println!(
            "  layer-cost memo {}/{} hits ({:.0}%)",
            m.cost_hits, lookups,
            100.0 * hit_rate
        );
    }

    // --- LLM decode sweep: transformer layers through the same engine ---
    // Decode-shaped opt-1.3b (matmul m=1 + attention vs a 2048-token KV
    // cache) exercises the bandwidth-bound corner of the dataflow model;
    // points/s here is the planning rate for LLM accelerator sweeps.
    let llm = vec![NamedWorkload::new(
        "opt-1.3b/decode",
        qappa::workloads::shape_for_phase(
            &qappa::workloads::opt_1p3b(),
            qappa::workloads::Phase::Decode,
            2048,
        ),
    )];
    println!(
        "\n=== llm decode sweep: {} configs x opt-1.3b decode (ctx 2048) ===",
        opts.space.len()
    );
    {
        let mut o = opts.clone();
        o.chunk = 1024;
        let engine = SweepEngine::new(backend.get(), &o);
        let r = Bench::new("sweep/llm_sweep_points_per_s")
            .warmup(1)
            .samples(3)
            .run_with_units(o.space.len() as f64, "points", || {
                engine.sweep_type(&model, PeType::Int16, &llm).expect("llm sweep");
            });
        let m = engine.memo_stats();
        let lookups = m.cost_hits + m.cost_misses;
        let hit_rate =
            if lookups > 0 { m.cost_hits as f64 / lookups as f64 } else { 0.0 };
        r.print();
        report.push(&r);
        report.metric("memo_hit_rate/llm-decode", hit_rate);
        println!(
            "  layer-cost memo {}/{} hits ({:.0}%)",
            m.cost_hits, lookups,
            100.0 * hit_rate
        );
    }

    // Measurement mode: QAPPA_BENCH_JSON=path emits the machine-readable
    // trajectory (tools/bench.sh -> BENCH_sweep.json).
    if let Some(path) = report.write_if_requested().expect("write bench json") {
        println!("wrote {path}");
    }
}
