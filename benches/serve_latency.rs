//! Bench: warm-session serving throughput vs cold per-process invocation.
//!
//! The whole point of the `qappa::api` session facade is that models train
//! once and every subsequent query runs at sweep speed.  This bench pins
//! that trajectory with three numbers:
//!
//! * `serve/warm_explore` — repeat `explore` requests against one warm
//!   session (pure cache hits; the serving steady state);
//! * `serve/warm_analyze` + `serve/loop_overhead` — the analytical query
//!   path and the full JSON-lines round trip (parse → dispatch →
//!   serialize) per request;
//! * `serve/cold_session` — a fresh session per request (what per-process
//!   CLI invocation pays: 4 training passes before the sweep).

use qappa::api::{
    serve, AnalyzeRequest, BackendChoice, ExploreRequest, Qappa, ServeOptions, SynthRequest,
};
use qappa::config::{AcceleratorConfig, PeType};
use qappa::coordinator::{DesignSpace, DseOptions};
use qappa::model::CvConfig;
use qappa::util::bench::Bench;

fn session() -> Qappa {
    Qappa::builder()
        .backend(BackendChoice::Native)
        .options(DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 128,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
            seed: 7,
            workers: qappa::util::pool::default_workers(),
            sigma: 0.02,
            chunk: 32,
            topk: 8,
        })
        .build()
}

fn main() {
    let explore_req = ExploreRequest { workloads: vec!["resnet34".into()], precision: None };
    let analyze_req =
        AnalyzeRequest::new("resnet34", AcceleratorConfig::default_with(PeType::LightPe1));

    // -------------------------------------------------------------- warm
    let warm = session();
    warm.explore(&explore_req).expect("prime session");
    println!(
        "=== serve latency: tiny space ({} configs/type), backend={} ===",
        warm.options().space.len(),
        warm.backend_name().expect("backend")
    );
    Bench::new("serve/warm_explore")
        .warmup(1)
        .samples(10)
        .run_with_units(1.0, "req", || warm.explore(&explore_req).expect("explore"))
        .print();
    assert_eq!(warm.store().misses(), 4, "warm explores must not retrain");

    Bench::new("serve/warm_analyze")
        .warmup(2)
        .samples(20)
        .run_with_units(1.0, "req", || warm.analyze(&analyze_req).expect("analyze"))
        .print();

    // Full JSON-lines round trip: parse -> dispatch -> serialize, 64
    // analyze + synth requests per iteration through the serve loop.
    let mut batch = String::new();
    for id in 0..64u64 {
        if id % 2 == 0 {
            batch.push_str(&format!(
                "{{\"id\":{id},\"op\":\"analyze\",\"params\":{}}}\n",
                analyze_req.to_json()
            ));
        } else {
            let synth = SynthRequest { config: AcceleratorConfig::default_with(PeType::Int16) };
            batch.push_str(&format!(
                "{{\"id\":{id},\"op\":\"synth\",\"params\":{}}}\n",
                synth.to_json()
            ));
        }
    }
    Bench::new("serve/loop_overhead(64 reqs)")
        .warmup(1)
        .samples(10)
        .run_with_units(64.0, "req", || {
            let stats = serve(
                &warm,
                batch.as_bytes(),
                std::io::sink(),
                &ServeOptions { concurrency: 1 },
            )
            .expect("serve");
            assert_eq!(stats.errors, 0);
        })
        .print();

    // -------------------------------------------------------------- cold
    // What every per-process CLI invocation pays: train-then-answer.
    Bench::new("serve/cold_session_explore")
        .warmup(0)
        .samples(3)
        .run_with_units(1.0, "req", || {
            let cold = session();
            cold.explore(&explore_req).expect("cold explore")
        })
        .print();

    println!(
        "\nwarm explores answered from {} cached models ({} hits so far); a cold\n\
         session re-pays 4 training passes per request — the gap is the case\n\
         for `qappa serve`.",
        warm.store().misses(),
        warm.store().hits()
    );
}
