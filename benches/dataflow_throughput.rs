//! Bench: row-stationary dataflow evaluation throughput (layers/s and
//! full-network evals/s) — step 4 of the DSE pipeline.

use qappa::config::{AcceleratorConfig, PeType};
use qappa::dataflow::evaluate_network;
use qappa::synth::oracle::energy_params;
use qappa::util::bench::Bench;
use qappa::util::pool::{default_workers, parallel_map};
use qappa::workloads;

fn main() {
    let cfg = AcceleratorConfig::default_with(PeType::Int16);
    let ep = energy_params(&cfg);

    for wl in ["vgg16", "resnet34", "resnet50"] {
        let layers = workloads::by_name(wl).unwrap();
        Bench::new(&format!("dataflow/{wl}_single_eval"))
            .warmup(2)
            .samples(10)
            .run_with_units(layers.len() as f64, "layers", || {
                evaluate_network(&cfg, &ep, &layers).cycles
            })
            .print();
    }

    // Whole-grid evaluation (the DSE inner loop) for one PE type.
    let space = qappa::coordinator::space::DesignSpace::default();
    let cfgs = space.enumerate(PeType::LightPe1);
    let layers = workloads::resnet34();
    let w = default_workers();
    Bench::new(&format!("dataflow/resnet34_grid_{}cfgs_x{w}", cfgs.len()))
        .warmup(1)
        .samples(3)
        .run_with_units(cfgs.len() as f64, "configs", || {
            parallel_map(&cfgs, w, |c| {
                let ep = energy_params(c);
                evaluate_network(c, &ep, &layers).energy_mj
            })
            .len()
        })
        .print();
}
