//! Ablation: PPA-model accuracy vs synthesis-jitter amplitude.
//!
//! Sweeps the oracle's noise sigma and reports holdout R² / MAPE — shows
//! the regression degrades gracefully as the "synthesis tool" gets noisier
//! (and that Figure-2-quality fits do not depend on a conveniently quiet
//! oracle).

use qappa::coordinator::report::fig2_accuracy;
use qappa::coordinator::DseOptions;
use qappa::model::native::NativeBackend;
use qappa::util::bench::Bench;
use qappa::util::table::Table;

fn main() {
    let backend = NativeBackend::new(7);
    println!("=== ablation: model accuracy vs synthesis jitter ===");
    let mut t = Table::new(&["sigma", "min_R2", "mean_R2", "max_MAPE_%"]);
    for sigma in [0.0, 0.01, 0.03, 0.06, 0.10] {
        let mut opts = DseOptions::default();
        opts.sigma = sigma;
        opts.train_per_type = 256;
        let mut rows = None;
        Bench::new(&format!("ablation_noise/sigma_{sigma}"))
            .warmup(0)
            .samples(3)
            .run(|| {
                rows = Some(fig2_accuracy(&backend, &opts, 96).expect("fig2"));
            })
            .print();
        let rows = rows.unwrap();
        let min_r2 = rows.iter().map(|r| r.r2).fold(f64::INFINITY, f64::min);
        let mean_r2 = rows.iter().map(|r| r.r2).sum::<f64>() / rows.len() as f64;
        let max_mape = rows.iter().map(|r| r.mape).fold(0.0, f64::max);
        t.row(vec![
            format!("{sigma:.2}"),
            format!("{min_r2:.4}"),
            format!("{mean_r2:.4}"),
            format!("{max_mape:.2}"),
        ]);
    }
    print!("{}", t.render());
}
