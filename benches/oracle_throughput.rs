//! Bench: synthesis-oracle throughput (configs/s), single-threaded and
//! with the worker fleet — the "how fast is ground truth" baseline that
//! motivates the regression models.

use qappa::config::PeType;
use qappa::coordinator::space::DesignSpace;
use qappa::synth::oracle::synthesize;
use qappa::util::bench::Bench;
use qappa::util::pool::{default_workers, parallel_map};

fn main() {
    let space = DesignSpace::default();
    let cfgs = space.sample(PeType::Int16, 2048, 1);
    println!("=== synthesis oracle throughput ({} configs) ===", cfgs.len());

    Bench::new("oracle/serial")
        .warmup(1)
        .samples(8)
        .run_with_units(cfgs.len() as f64, "configs", || {
            let mut acc = 0.0;
            for c in &cfgs {
                acc += synthesize(c).area_mm2;
            }
            acc
        })
        .print();

    let w = default_workers();
    Bench::new(&format!("oracle/parallel_x{w}"))
        .warmup(1)
        .samples(8)
        .run_with_units(cfgs.len() as f64, "configs", || {
            parallel_map(&cfgs, w, synthesize).len()
        })
        .print();
}
