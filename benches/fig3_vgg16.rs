//! Bench: regenerate Figure 3 (VGG-16 design-space exploration).
#[path = "common.rs"]
mod common;

fn main() {
    common::dse_figure_bench(3, "vgg16");
}
