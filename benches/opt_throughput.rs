//! Bench: guided-optimizer throughput (evaluations/sec) and search quality
//! (hypervolume vs budget) on the paper-scale space x MobileNetV1.
//!
//! Runs NSGA-II and the random baseline at increasing evaluation budgets
//! through one warm unified cross-precision model, reporting evals/s plus
//! the final archive hypervolume per (strategy, budget) cell — the
//! hypervolume-vs-budget curve is the optimizer's perf trajectory, emitted
//! machine-readable via `QAPPA_BENCH_JSON` (tools/bench.sh ->
//! `BENCH_opt.json`).

use qappa::config::ALL_PE_TYPES;
use qappa::coordinator::{DseOptions, ModelStore};
use qappa::model::native::NativeBackend;
use qappa::opt::{
    run_optimize, Constraints, Objective, OptOptions, OptProblem, SearchSpace, StrategyKind,
};
use qappa::util::bench::{Bench, BenchReport};
use qappa::workloads;

fn main() {
    let backend = NativeBackend::new(qappa::config::QUANT_NUM_FEATURES);
    let mut opts = DseOptions::default();
    opts.train_per_type = 192;
    let store = ModelStore::new();
    let palette = ALL_PE_TYPES.to_vec();
    let model = store
        .get_or_train_quant(&backend, &opts, &palette)
        .expect("train unified model");
    let layers = workloads::mobilenetv1();

    println!(
        "=== guided optimizer: {} hw points x {} precision cells, {} layers (mobilenetv1) ===",
        opts.space.len(),
        palette.len(),
        layers.len()
    );
    let mut report = BenchReport::new();
    for budget in [1000usize, 4000] {
        for kind in [StrategyKind::Nsga2, StrategyKind::Random] {
            let label = kind.label();
            let mut hv = 0.0f64;
            let mut evals = 0usize;
            let mut frontier = 0usize;
            let mut memo = qappa::dataflow::MemoStats::default();
            let r = Bench::new(&format!("opt/{label}/budget={budget}"))
                .warmup(0)
                .samples(3)
                .run_with_units(budget as f64, "evals", || {
                    let search = SearchSpace::new(&opts.space, palette.clone(), &layers, true)
                        .expect("search space");
                    let problem = OptProblem {
                        search,
                        objectives: vec![Objective::PerfPerArea, Objective::Energy],
                        constraints: Constraints::default(),
                        accuracy: None,
                    };
                    let oopts = OptOptions {
                        strategy: kind,
                        budget,
                        pop: 64,
                        seed: 7,
                        ..Default::default()
                    };
                    let res = run_optimize(&backend, &model, &problem, &oopts, opts.workers)
                        .expect("optimize");
                    hv = res.hypervolume;
                    evals = res.evaluated;
                    frontier = res.frontier.len();
                    memo = res.memo;
                });
            let lookups = memo.cost_hits + memo.cost_misses;
            let hit_rate =
                if lookups > 0 { memo.cost_hits as f64 / lookups as f64 } else { 0.0 };
            r.print();
            println!(
                "  hypervolume {hv:.6e}, frontier {frontier}, {evals} evals, \
                 memo {}/{} hits ({:.0}%)",
                memo.cost_hits,
                lookups,
                100.0 * hit_rate
            );
            report.push(&r);
            report.metric(&format!("hypervolume/{label}/budget={budget}"), hv);
            report.metric(&format!("frontier/{label}/budget={budget}"), frontier as f64);
            report.metric(&format!("memo_hit_rate/{label}/budget={budget}"), hit_rate);
        }
    }
    // Three-objective accuracy search: the per-genome noise-model estimate
    // rides the scoring loop, so evals/s here gates the accuracy model's
    // overhead against the classic two-objective path above.
    println!("=== accuracy objective: latency x energy x accuracy (noise-model proxy) ===");
    {
        let budget = 1000usize;
        let mut hv = 0.0f64;
        let mut evals = 0usize;
        let mut frontier = 0usize;
        let r = Bench::new(&format!("opt/nsga2-accuracy/budget={budget}"))
            .warmup(0)
            .samples(3)
            .run_with_units(budget as f64, "evals", || {
                let search = SearchSpace::new(&opts.space, palette.clone(), &layers, true)
                    .expect("search space");
                let problem = OptProblem {
                    search,
                    objectives: vec![
                        Objective::Latency,
                        Objective::Energy,
                        Objective::Accuracy,
                    ],
                    constraints: Constraints {
                        min_accuracy: Some(0.90),
                        ..Default::default()
                    },
                    accuracy: None,
                };
                let oopts = OptOptions {
                    strategy: StrategyKind::Nsga2,
                    budget,
                    pop: 64,
                    seed: 7,
                    ..Default::default()
                };
                let res = run_optimize(&backend, &model, &problem, &oopts, opts.workers)
                    .expect("optimize");
                hv = res.hypervolume;
                evals = res.evaluated;
                frontier = res.frontier.len();
            });
        r.print();
        println!("  hypervolume {hv:.6e}, frontier {frontier}, {evals} evals");
        report.push(&r);
        report.metric(&format!("hypervolume/nsga2-accuracy/budget={budget}"), hv);
        report.metric(&format!("frontier/nsga2-accuracy/budget={budget}"), frontier as f64);
    }
    if let Some(path) = report.write_if_requested().expect("write bench json") {
        println!("wrote {path}");
    }
}
