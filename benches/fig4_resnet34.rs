//! Bench: regenerate Figure 4 (ResNet-34 design-space exploration).
#[path = "common.rs"]
mod common;

fn main() {
    common::dse_figure_bench(4, "resnet34");
}
