//! Bench: concurrent TCP serve throughput vs sequential stdio sessions.
//!
//! The acceptance bar for the network serve path: `qappa loadgen` against
//! one warm TCP server (4 connections x 25 lockstep requests, models
//! trained once per process) must sustain at least 4x the throughput of 4
//! sequential cold stdio sessions answering the same request mix — the
//! multiplexing + shared-store win over per-client processes.
//!
//! Emits `BENCH_serve.json` through the `BenchReport` sink when
//! `QAPPA_BENCH_JSON` is set; `tools/bench_check.py` gates
//! `serve/p99_ms` (lower is better) and the loadgen throughput
//! (higher is better) against `tools/bench_baseline.json`.

use std::sync::Arc;

use qappa::api::{
    run_loadgen, serve, BackendChoice, ExploreRequest, LoadgenOptions, Qappa, RequestBody,
    RequestMix, ServeOptions, ServeRequest, TcpServer, TransportOptions,
};
use qappa::coordinator::{DesignSpace, DseOptions};
use qappa::model::CvConfig;
use qappa::util::bench::{Bench, BenchReport};

const CONNECTIONS: usize = 4;
const REQUESTS: usize = 25;

fn session() -> Qappa {
    Qappa::builder()
        .backend(BackendChoice::Native)
        .options(DseOptions {
            space: DesignSpace::tiny(),
            train_per_type: 64,
            cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
            seed: 7,
            workers: qappa::util::pool::default_workers(),
            sigma: 0.02,
            chunk: 32,
            topk: 8,
        })
        .build()
}

/// The stdio baseline: 4 sequential `qappa serve` sessions, each a fresh
/// process in miniature (new session, models retrained), answering the
/// same explore mix the loadgen connections send.
fn stdio_sequential_sessions() -> f64 {
    let mut batch = String::new();
    for k in 0..REQUESTS {
        let req = ServeRequest {
            id: Some(k as u64),
            body: RequestBody::Explore(ExploreRequest {
                workloads: vec!["vgg16".into()],
                precision: None,
            }),
        };
        batch.push_str(&req.to_json().to_string());
        batch.push('\n');
    }
    let t0 = std::time::Instant::now();
    for _ in 0..CONNECTIONS {
        let cold = session();
        let stats = serve(
            &cold,
            batch.as_bytes(),
            std::io::sink(),
            &ServeOptions { concurrency: 1 },
        )
        .expect("stdio serve");
        assert_eq!(stats.errors, 0);
    }
    let dt = t0.elapsed().as_secs_f64();
    (CONNECTIONS * REQUESTS) as f64 / dt
}

fn main() {
    let mut report = BenchReport::new();
    let units = (CONNECTIONS * REQUESTS) as f64;

    // ---------------------------------------------------------------- TCP
    let session = Arc::new(session());
    let mut server = TcpServer::bind(session.clone(), "127.0.0.1:0", TransportOptions::default())
        .expect("bind");
    let addr = server.local_addr().to_string();
    println!(
        "=== serve throughput: {CONNECTIONS} connections x {REQUESTS} requests, \
         tiny space, backend=native ==="
    );

    let opts = LoadgenOptions {
        connections: CONNECTIONS,
        requests: REQUESTS,
        mix: RequestMix::Explore,
        ..Default::default()
    };
    let mut last = None;
    let r = Bench::new(&format!("serve/tcp_loadgen({CONNECTIONS}x{REQUESTS})"))
        .warmup(1)
        .samples(5)
        .run_with_units(units, "req", || {
            let rep = run_loadgen(&addr, &opts).expect("loadgen");
            assert_eq!(rep.errors, 0, "loadgen must run error-free");
            last = Some(rep);
        });
    r.print();
    report.push(&r);
    let rep = last.expect("loadgen report");
    println!(
        "loadgen: {:.1} req/s, p50 {:.2} ms, p99 {:.2} ms (max {:.2} ms)",
        rep.throughput_per_s, rep.p50_ms, rep.p99_ms, rep.max_ms
    );
    report.metric("serve/p50_ms", rep.p50_ms);
    report.metric("serve/p99_ms", rep.p99_ms);
    report.metric("serve/loadgen_throughput_per_s", rep.throughput_per_s);

    // Trained exactly once per process, no matter how many connections,
    // warmups and samples hit the server.
    assert_eq!(session.store().misses(), 4, "models must train once per process");

    server.shutdown();

    // Attach the process metrics registry to the artifact: serve counters
    // and the server-side request-latency summary ride along in
    // BENCH_serve.json.  Names avoid the *_per_s / *_ms gate suffixes on
    // purpose — these are informational context next to the gated numbers.
    let snap = qappa::obs::registry().snapshot();
    for key in [
        "serve.requests",
        "serve.ok",
        "serve.errors",
        "serve.shed",
        "serve.coalesced",
        "serve.connections",
    ] {
        if let Some(v) = snap.counters.get(key) {
            report.metric(&format!("metrics/{key}"), *v as f64);
        }
    }
    if let Some(h) = snap.histograms.get("serve.request_ms") {
        report.metric("metrics/serve.request_ms.count", h.count as f64);
        report.metric("metrics/serve.request_ms.p50", h.p50_ms);
        report.metric("metrics/serve.request_ms.p95", h.p95_ms);
        report.metric("metrics/serve.request_ms.p99", h.p99_ms);
    }

    // -------------------------------------------------------------- stdio
    // The baseline is intentionally *one* measurement, not a Bench loop: 4
    // cold sessions retrain 16 models as a real 4-process client would.
    let stdio_per_s = stdio_sequential_sessions();
    println!("stdio baseline: {stdio_per_s:.1} req/s (4 sequential cold sessions)");
    report.metric("serve/stdio_cold_4_sessions_per_s", stdio_per_s);

    let speedup = rep.throughput_per_s / stdio_per_s;
    println!("speedup vs stdio: {speedup:.1}x");
    report.metric("serve/speedup_vs_stdio", speedup);
    assert!(
        speedup >= 4.0,
        "warm TCP serve must sustain >= 4x the sequential stdio baseline \
         (got {speedup:.2}x: {:.1} vs {stdio_per_s:.1} req/s)",
        rep.throughput_per_s
    );

    if let Some(path) = report.write_if_requested().expect("write bench json") {
        println!("wrote {path}");
    }
}
