//! Shared bench plumbing (included by `#[path]` from each bench target).

use std::sync::Arc;

use qappa::coordinator::report::{dse_summary_table, fig2_accuracy, fig2_table};
use qappa::coordinator::{run_dse, DseOptions};
use qappa::model::native::NativeBackend;
use qappa::model::Backend;
use qappa::runtime::{ArtifactRuntime, Engine, XlaBackend};
use qappa::util::bench::Bench;
use qappa::workloads;

/// Backend holder usable from bench mains.
pub enum AnyBackend {
    Native(NativeBackend),
    Xla(XlaBackend, Arc<Engine>),
}

impl AnyBackend {
    pub fn auto() -> AnyBackend {
        let dir = ArtifactRuntime::artifacts_dir_default();
        if dir.join("manifest.json").exists() {
            if let Ok(engine) = Engine::start(&dir) {
                let engine = Arc::new(engine);
                return AnyBackend::Xla(XlaBackend::new(engine.clone()), engine);
            }
        }
        AnyBackend::Native(NativeBackend::new(7))
    }

    pub fn native() -> AnyBackend {
        AnyBackend::Native(NativeBackend::new(7))
    }

    pub fn get(&self) -> &dyn Backend {
        match self {
            AnyBackend::Native(b) => b,
            AnyBackend::Xla(b, _) => b,
        }
    }
}

/// Run one figure-3/4/5 style DSE bench: times the full pipeline and prints
/// the figure's summary table (the regenerated "figure").
pub fn dse_figure_bench(fig: u32, workload: &str) {
    let backend = AnyBackend::auto();
    let layers = workloads::by_name(workload).expect("workload");
    let opts = DseOptions::default();

    println!(
        "=== Figure {fig}: {workload} design space ({} configs/type, backend={}) ===",
        opts.space.len(),
        backend.get().name()
    );
    let mut last = None;
    let r = Bench::new(&format!("fig{fig}/{workload}/dse_pipeline"))
        .warmup(1)
        .samples(5)
        .run_with_units(4.0 * opts.space.len() as f64, "configs", || {
            last = Some(run_dse(backend.get(), &layers, workload, &opts).expect("dse"));
        });
    r.print();
    let res = last.unwrap();
    println!("anchor: {}", res.anchor.cfg.key());
    print!("{}", dse_summary_table(&res).render());
}

/// Figure-2 style accuracy bench.
pub fn fig2_bench() {
    let backend = AnyBackend::auto();
    let opts = DseOptions::default();
    println!(
        "=== Figure 2: PPA model accuracy (backend={}) ===",
        backend.get().name()
    );
    let mut rows = None;
    Bench::new("fig2/train+holdout_score")
        .warmup(1)
        .samples(5)
        .run(|| {
            rows = Some(fig2_accuracy(backend.get(), &opts, 128).expect("fig2"));
        })
        .print();
    print!("{}", fig2_table(&rows.unwrap()).render());
}
