//! Bench: PPA model evaluation throughput — the framework's hot path.
//!
//! Sweeps request batch size through the XLA engine's dynamic batcher
//! (ablation: batching amortization) and compares against the native Rust
//! evaluator and the raw synthesis oracle.

#[path = "common.rs"]
mod common;

use std::sync::Arc;

use common::AnyBackend;
use qappa::config::PeType;
use qappa::coordinator::space::DesignSpace;
use qappa::model::{num_features, Backend, M};
use qappa::synth::oracle::synthesize;
use qappa::util::bench::Bench;

fn main() {
    let degree = 2usize;
    let d = 7usize;
    let p = num_features(d, degree);
    let coef: Vec<f32> = (0..p * M).map(|i| (i as f32 * 0.017).sin()).collect();

    let space = DesignSpace::default();
    let cfgs = space.sample(PeType::LightPe1, 8192, 3);
    let mut x = Vec::with_capacity(cfgs.len() * d);
    for c in &cfgs {
        for f in c.features() {
            x.push(f as f32);
        }
    }
    let n = cfgs.len();
    println!("=== predict throughput (degree {degree}, {n} design points) ===");

    // Baseline: the oracle itself (what the model replaces).
    Bench::new("oracle/ground_truth_1024")
        .warmup(1)
        .samples(5)
        .run_with_units(1024.0, "configs", || {
            cfgs[..1024].iter().map(|c| synthesize(c).power_mw).sum::<f64>()
        })
        .print();

    // Native evaluator.
    let native = AnyBackend::native();
    Bench::new("predict/native_full")
        .warmup(1)
        .samples(8)
        .run_with_units(n as f64, "rows", || {
            native.get().predict(&x, n, &coef, degree).unwrap().len()
        })
        .print();

    // XLA engine at several request granularities (batcher ablation).
    let xla = AnyBackend::auto();
    if xla.get().name() != "xla" {
        println!("(artifacts not built — skipping XLA sweep)");
        return;
    }
    let AnyBackend::Xla(_, engine) = &xla else { unreachable!() };
    for chunk in [32usize, 128, 256, 1024, 8192] {
        let coef_arc = Arc::new(coef.clone());
        Bench::new(&format!("predict/xla_chunk_{chunk}"))
            .warmup(1)
            .samples(5)
            .run_with_units(n as f64, "rows", || {
                let mut total = 0usize;
                let mut off = 0;
                while off < n {
                    let take = (n - off).min(chunk);
                    let slab = x[off * 7..(off + take) * 7].to_vec();
                    total += engine
                        .predict(degree, coef_arc.clone(), slab, take)
                        .unwrap()
                        .len();
                    off += take;
                }
                total
            })
            .print();
    }
    use std::sync::atomic::Ordering::Relaxed;
    println!(
        "engine totals: {} rows / {} batches ({:.1} rows/batch avg), {} padded",
        engine.stats.predict_rows.load(Relaxed),
        engine.stats.predict_batches.load(Relaxed),
        engine.stats.predict_rows.load(Relaxed) as f64
            / engine.stats.predict_batches.load(Relaxed).max(1) as f64,
        engine.stats.predict_padded_rows.load(Relaxed)
    );
}
