//! Bench: MobileNet dataflow evaluation throughput — depthwise-separable
//! networks have ~3x the layer count of VGG-16 at ~1/25 the MACs, so they
//! stress the per-layer mapping overhead rather than the MAC loop.

use qappa::config::{AcceleratorConfig, PeType};
use qappa::dataflow::evaluate_network;
use qappa::synth::oracle::energy_params;
use qappa::util::bench::Bench;
use qappa::util::pool::{default_workers, parallel_map};
use qappa::workloads;

fn main() {
    for wl in ["mobilenetv1", "mobilenetv2"] {
        let layers = workloads::by_name(wl).unwrap();
        for ty in [PeType::Int16, PeType::LightPe1] {
            let cfg = AcceleratorConfig::default_with(ty);
            let ep = energy_params(&cfg);
            Bench::new(&format!("dataflow/{wl}_single_eval_{}", ty.label()))
                .warmup(2)
                .samples(10)
                .run_with_units(layers.len() as f64, "layers", || {
                    evaluate_network(&cfg, &ep, &layers).cycles
                })
                .print();
        }
    }

    // Whole-grid MobileNetV2 evaluation (the DSE inner loop).
    let space = qappa::coordinator::space::DesignSpace::default();
    let cfgs = space.enumerate(PeType::LightPe1);
    let layers = workloads::mobilenetv2();
    let w = default_workers();
    Bench::new(&format!("dataflow/mobilenetv2_grid_{}cfgs_x{w}", cfgs.len()))
        .warmup(1)
        .samples(3)
        .run_with_units(cfgs.len() as f64, "configs", || {
            parallel_map(&cfgs, w, |c| {
                let ep = energy_params(c);
                evaluate_network(c, &ep, &layers).energy_mj
            })
            .len()
        })
        .print();
}
