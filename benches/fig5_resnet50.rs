//! Bench: regenerate Figure 5 (ResNet-50 design-space exploration).
#[path = "common.rs"]
mod common;

fn main() {
    common::dse_figure_bench(5, "resnet50");
}
