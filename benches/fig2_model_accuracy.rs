//! Bench: regenerate Figure 2 (PPA model accuracy) and time the pipeline.
#[path = "common.rs"]
mod common;

fn main() {
    common::fig2_bench();
}
