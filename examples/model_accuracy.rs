//! Figure 2 reproduction: actual (synthesis oracle) vs estimated
//! (polynomial model) power / performance / area, per PE type.
//!
//! Run: `cargo run --release --example model_accuracy`
//! Writes `figures/fig2_accuracy.csv` and prints R² / MAPE per cell plus a
//! few sample actual-vs-predicted rows, mirroring the paper's scatter.

use std::sync::Arc;

use qappa::config::ALL_PE_TYPES;
use qappa::coordinator::explorer::train_models;
use qappa::coordinator::report::{fig2_accuracy, fig2_table};
use qappa::coordinator::DseOptions;
use qappa::model::native::NativeBackend;
use qappa::model::{predict_ppa, Backend};
use qappa::runtime::{ArtifactRuntime, Engine, XlaBackend};
use qappa::synth::oracle::synthesize;

fn main() {
    let dir = ArtifactRuntime::artifacts_dir_default();
    let engine = if dir.join("manifest.json").exists() {
        Some(Arc::new(Engine::start(&dir).expect("engine")))
    } else {
        None
    };
    let xla;
    let native;
    let backend: &dyn Backend = match &engine {
        Some(e) => {
            xla = XlaBackend::new(e.clone());
            &xla
        }
        None => {
            native = NativeBackend::new(7);
            &native
        }
    };
    println!("backend: {}", backend.name());

    let opts = DseOptions::default();
    let rows = fig2_accuracy(backend, &opts, 160).expect("fig2");
    let t = fig2_table(&rows);
    println!("\nFigure 2 — model accuracy on a fresh holdout (160 configs/type):");
    print!("{}", t.render());
    t.write_csv("figures/fig2_accuracy.csv").expect("csv");

    // A few raw actual-vs-predicted rows (the scatter's underlying data).
    let models = train_models(backend, &opts).expect("models");
    println!("\nsample actual vs predicted (first 4 holdout configs per type):");
    for ty in ALL_PE_TYPES {
        let cfgs = opts.space.sample(ty, 4, opts.seed ^ 0x601d);
        let mut feats = Vec::new();
        for c in &cfgs {
            feats.extend_from_slice(&c.features());
        }
        let preds = predict_ppa(backend, &models[&ty], &feats).expect("predict");
        for (c, p) in cfgs.iter().zip(&preds) {
            let a = synthesize(c).as_array();
            println!(
                "  {:<9} {}: power {:>8.2} vs {:>8.2} mW | fmax {:>7.1} vs {:>7.1} MHz | area {:>6.3} vs {:>6.3} mm2",
                ty.label(),
                c.key(),
                a[0], p[0], a[1], p[1], a[2], p[2]
            );
        }
    }
    println!("\nwrote figures/fig2_accuracy.csv");
}
