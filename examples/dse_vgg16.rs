//! End-to-end driver (EXPERIMENTS.md §E2E): the full QAPPA pipeline on the
//! real VGG-16 design space — the paper's Figure 3 experiment at full
//! scale, run through all three layers of the stack:
//!
//!   synthesis-oracle fleet (rust, parallel)
//!     -> k-fold CV polynomial fitting (AOT pallas/jax artifacts via PJRT)
//!     -> batched PPA prediction over the full grid (dynamic batcher)
//!     -> row-stationary dataflow evaluation of all 16 VGG-16 layers
//!     -> Pareto frontiers + the paper's normalized ratios.
//!
//! Run: `cargo run --release --example dse_vgg16 [-- --train N]`
//! Writes `figures/fig3_vgg16_{summary,scatter}.csv`.

use std::sync::Arc;

use qappa::config::{PeType, ALL_PE_TYPES};
use qappa::coordinator::report::{dse_scatter_table, dse_summary_table};
use qappa::coordinator::{run_dse, DseOptions};
use qappa::model::native::NativeBackend;
use qappa::model::Backend;
use qappa::runtime::{ArtifactRuntime, Engine, XlaBackend};
use qappa::workloads;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let train = args
        .iter()
        .position(|a| a == "--train")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(384usize);

    let dir = ArtifactRuntime::artifacts_dir_default();
    let engine = if dir.join("manifest.json").exists() {
        Some(Arc::new(Engine::start(&dir).expect("engine start")))
    } else {
        None
    };
    let xla;
    let native;
    let backend: &dyn Backend = match &engine {
        Some(e) => {
            xla = XlaBackend::new(e.clone());
            &xla
        }
        None => {
            native = NativeBackend::new(7);
            &native
        }
    };
    println!("backend: {}", backend.name());

    let layers = workloads::vgg16();
    let macs: u64 = layers.iter().map(|l| l.macs()).sum();
    println!(
        "workload: VGG-16, {} layers, {:.2} GMACs/inference",
        layers.len(),
        macs as f64 / 1e9
    );

    let mut opts = DseOptions::default();
    opts.train_per_type = train;
    println!(
        "space: {} configs/type x 4 types = {} designs; {} synthesized for training/type",
        opts.space.len(),
        4 * opts.space.len(),
        opts.train_per_type
    );

    let t0 = std::time::Instant::now();
    let res = run_dse(backend, &layers, "vgg16", &opts).expect("dse");
    let dt = t0.elapsed().as_secs_f64();

    println!("\nanchor (best INT16 perf/area): {}", res.anchor.cfg.key());
    println!(
        "anchor point: {:.1} inf/s, {:.3} inf/s/mm2, {:.2} mJ/inf, util {:.2}",
        res.anchor.throughput,
        res.anchor.perf_per_area,
        res.anchor.energy_mj,
        res.anchor.utilization
    );
    print!("{}", dse_summary_table(&res).render());

    // Paper headline (§4): LightPE-1 4.9x/4.9x, LightPE-2 4.1x/4.2x vs best
    // INT16; INT16 1.7x/1.4x vs best FP32.  We report the *validated*
    // ratios (winning configs re-synthesized by the oracle) — picking the
    // best of 19200 noisy predictions is optimistically biased.
    let (pa1, e1) = res.ratios_validated[&PeType::LightPe1];
    let (pa2, e2) = res.ratios_validated[&PeType::LightPe2];
    let (paf, ef) = res.ratios_validated[&PeType::Fp32];
    println!("\nheadline (VGG-16, oracle-validated):");
    println!("  LightPE-1 vs best INT16 : {:.2}x perf/area, {:.2}x energy (paper: 4.9x, 4.9x)", pa1, e1);
    println!("  LightPE-2 vs best INT16 : {:.2}x perf/area, {:.2}x energy (paper: 4.1x, 4.2x)", pa2, e2);
    println!("  INT16 vs best FP32      : {:.2}x perf/area, {:.2}x energy (paper: 1.7x, 1.4x)", 1.0 / paf, 1.0 / ef);

    for ty in ALL_PE_TYPES {
        let m = &res.models[&ty];
        println!(
            "  model[{}]: degree={} lambda={:.0e}",
            ty.label(),
            m.degree,
            m.lambda
        );
    }
    if let Some(e) = &engine {
        use std::sync::atomic::Ordering::Relaxed;
        println!(
            "engine: {} predict rows in {} batches, {} fit calls, {} loss calls",
            e.stats.predict_rows.load(Relaxed),
            e.stats.predict_batches.load(Relaxed),
            e.stats.fit_calls.load(Relaxed),
            e.stats.loss_calls.load(Relaxed)
        );
    }

    dse_summary_table(&res)
        .write_csv("figures/fig3_vgg16_summary.csv")
        .expect("write summary");
    dse_scatter_table(&res)
        .write_csv("figures/fig3_vgg16_scatter.csv")
        .expect("write scatter");
    println!("\nwrote figures/fig3_vgg16_{{summary,scatter}}.csv in {dt:.2}s total");
}
