//! RTL generation + gate-level verification — the paper's "automatically
//! generated RTL" and VCS-verification loop, self-contained.
//!
//! For each PE type: emit the Verilog bundle, elaborate the structural
//! arithmetic cores into gate netlists, simulate them on random vectors
//! against arithmetic golden models, and report the measured switching
//! activity next to the power model's assumed activity factors.
//!
//! Run: `cargo run --release --example rtl_verify`
//! Writes the generated Verilog under `figures/rtl/`.

use qappa::config::{AcceleratorConfig, PeType, ALL_PE_TYPES};
use qappa::rtl::netlist::{int16_multiplier, light_term};
use qappa::rtl::sim::{verify_int16_multiplier, verify_light_term};
use qappa::rtl::verilog::generate;
use qappa::synth::gates::GateLib;
use qappa::synth::mac::mac_unit;

fn main() {
    std::fs::create_dir_all("figures/rtl").expect("mkdir");
    let lib = GateLib::freepdk45();

    println!("== RTL generation ==");
    for ty in ALL_PE_TYPES {
        let cfg = AcceleratorConfig::default_with(ty);
        let v = generate(&cfg);
        let path = format!(
            "figures/rtl/qappa_{}.v",
            ty.label().to_ascii_lowercase().replace('-', "_")
        );
        std::fs::write(&path, &v).expect("write verilog");
        println!(
            "  {:<10} -> {} ({} modules, {} bytes)",
            ty.label(),
            path,
            v.matches("endmodule").count(),
            v.len()
        );
    }

    println!("\n== gate-level functional verification (2000 vectors each) ==");
    let act_mult = verify_int16_multiplier(2000, 0xfeed).expect("int16 core");
    let nl_mult = int16_multiplier();
    println!(
        "  int16 16x16 multiplier : OK   {} gates, measured activity {:.3} (power model assumes {:.2})",
        nl_mult.num_gates(),
        act_mult,
        mac_unit(&lib, PeType::Int16).activity
    );
    for (ty, w) in [(PeType::LightPe1, 20u32), (PeType::LightPe2, 24u32)] {
        let act = verify_light_term(w, 2000, 0xf00d).expect("light core");
        let nl = light_term(w);
        println!(
            "  light shift-add  w={w}  : OK   {} gates, measured activity {:.3} (power model assumes {:.2})",
            nl.num_gates(),
            act,
            mac_unit(&lib, ty).activity
        );
    }

    println!("\n== the quantization-aware hardware claim, at gate level ==");
    let mult_gates = int16_multiplier().num_gates();
    let light_gates = light_term(20).num_gates();
    println!(
        "  INT16 multiplier core : {mult_gates} gates\n  LightPE-1 term core   : {light_gates} gates  ({:.1}x smaller)",
        mult_gates as f64 / light_gates as f64
    );
    println!("\nrtl_verify OK");
}
