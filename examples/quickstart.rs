//! Quickstart: the whole QAPPA flow in one minute on a tiny space.
//!
//! 1. synthesize a handful of configs per PE type (ground truth),
//! 2. fit the polynomial PPA models (k-fold CV),
//! 3. sweep a small grid with the fitted models,
//! 4. print a mini Pareto table for a toy conv workload.
//!
//! Run: `cargo run --release --example quickstart`
//! (uses the XLA artifacts when `artifacts/` exists, else the native
//! backend — both produce the same numbers to ~1e-3.)

use std::sync::Arc;

use qappa::config::ALL_PE_TYPES;
use qappa::coordinator::report::dse_summary_table;
use qappa::coordinator::space::DesignSpace;
use qappa::coordinator::{run_dse, DseOptions};
use qappa::dataflow::Layer;
use qappa::model::native::NativeBackend;
use qappa::model::{Backend, CvConfig};
use qappa::runtime::{ArtifactRuntime, Engine, XlaBackend};

enum AnyBackend {
    Native(NativeBackend),
    Xla(XlaBackend),
}

impl AnyBackend {
    fn auto() -> AnyBackend {
        let dir = ArtifactRuntime::artifacts_dir_default();
        if dir.join("manifest.json").exists() {
            match Engine::start(&dir) {
                Ok(engine) => {
                    println!("backend: XLA artifacts from {}", dir.display());
                    return AnyBackend::Xla(XlaBackend::new(Arc::new(engine)));
                }
                Err(e) => eprintln!("XLA engine unavailable ({e}); falling back to native"),
            }
        } else {
            println!("backend: native (run `make artifacts` for the XLA path)");
        }
        AnyBackend::Native(NativeBackend::new(7))
    }

    fn get(&self) -> &dyn Backend {
        match self {
            AnyBackend::Native(b) => b,
            AnyBackend::Xla(b) => b,
        }
    }
}

fn main() {
    let backend = AnyBackend::auto();

    // --- a toy workload ---------------------------------------------------
    let layers = vec![
        Layer::conv("conv1", 3, 16, 32, 32, 3, 1, 1),
        Layer::conv("conv2", 16, 32, 16, 16, 3, 1, 1),
        Layer::fc("fc", 2048, 10),
    ];

    let opts = DseOptions {
        space: DesignSpace::tiny(),
        train_per_type: 128,
        cv: CvConfig { k: 3, degrees: vec![1, 2], lambdas: vec![1e-3, 1e-2], seed: 1 },
        seed: 42,
        workers: 4,
        sigma: 0.03,
        ..DseOptions::default()
    };

    println!(
        "design space: {} configs per PE type, {} training samples each",
        opts.space.len(),
        opts.train_per_type
    );

    let res = run_dse(backend.get(), &layers, "quickstart", &opts).expect("dse");

    println!("\nanchor (best INT16 perf/area): {}", res.anchor.cfg.key());
    print!("{}", dse_summary_table(&res).render());

    println!("\nselected models:");
    for ty in ALL_PE_TYPES {
        let m = &res.models[&ty];
        println!(
            "  {:<10} degree={} lambda={:.0e}  (train n={})",
            ty.label(),
            m.degree,
            m.lambda,
            m.n_train
        );
    }
    println!("\nquickstart OK");
}
