#!/usr/bin/env bash
# Measurement mode: run the perf benches and emit machine-readable
# BENCH_*.json documents (sweep throughput + peak-resident counters +
# the LLM decode sweep rate [llm_sweep_points_per_s], optimizer evals/s
# + hypervolume-vs-budget + memo hit rates, concurrent serve latency
# percentiles + loadgen throughput) at the repo root.  CI
# uploads them as artifacts, so the repo accumulates a perf trajectory per
# commit.
#
# Usage: tools/bench.sh [--sweep-only|--opt-only|--serve-only|--check|--bless]
#
#   --check   run all benches, then gate the fresh throughputs and serve
#             latency metrics against the checked-in
#             tools/bench_baseline.json (tools/bench_check.py); exits
#             nonzero on a perf regression past the tolerance band.
#   --bless   run all benches, then rewrite the baseline from the fresh
#             results — do this on quiet, representative hardware when a
#             perf change is intentional.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "bench.sh: cargo unavailable; skipping measurement run" >&2
    exit 0
fi

run_bench() {
    local bench="$1" out="$2"
    echo "==> cargo bench --bench $bench  (-> $out)"
    QAPPA_BENCH_JSON="$PWD/$out" cargo bench --bench "$bench"
    test -s "$out" || { echo "bench.sh: $out was not written" >&2; exit 1; }
}

bench_check() {
    local mode="$1"
    if ! command -v python3 >/dev/null 2>&1; then
        echo "bench.sh: python3 unavailable; skipping baseline $mode" >&2
        return 0
    fi
    python3 tools/bench_check.py "$mode" BENCH_sweep.json BENCH_opt.json BENCH_serve.json
}

# The serve bench attaches the process metrics registry snapshot
# (metrics/serve.* keys) to its artifact; fail loudly if that wiring ever
# drops out instead of silently shipping a thinner BENCH_serve.json.
check_serve_metrics() {
    if ! command -v python3 >/dev/null 2>&1; then
        echo "bench.sh: python3 unavailable; skipping serve metrics check" >&2
        return 0
    fi
    python3 - <<'EOF'
import json, sys
doc = json.load(open("BENCH_serve.json"))
m = doc.get("metrics", {})
need = ["metrics/serve.requests", "metrics/serve.ok", "metrics/serve.request_ms.p50"]
missing = [k for k in need if k not in m]
if missing:
    sys.exit("bench.sh: BENCH_serve.json is missing registry metrics: %s" % missing)
print("bench.sh: BENCH_serve.json carries the metrics registry snapshot")
EOF
}

mode="${1:-all}"
case "$mode" in
    --sweep-only) run_bench sweep_throughput BENCH_sweep.json ;;
    --opt-only)   run_bench opt_throughput BENCH_opt.json ;;
    --serve-only) run_bench serve_throughput BENCH_serve.json; check_serve_metrics ;;
    all|--check|--bless)
        run_bench sweep_throughput BENCH_sweep.json
        run_bench opt_throughput BENCH_opt.json
        run_bench serve_throughput BENCH_serve.json
        check_serve_metrics
        if [ "$mode" = --check ]; then bench_check --check; fi
        if [ "$mode" = --bless ]; then bench_check --bless; fi
        ;;
    *)
        echo "bench.sh: unknown mode '$mode' (expected --sweep-only|--opt-only|--serve-only|--check|--bless)" >&2
        exit 2
        ;;
esac

echo "OK: bench measurement artifacts written"
