#!/usr/bin/env bash
# Measurement mode: run the perf benches and emit machine-readable
# BENCH_*.json documents (sweep throughput + peak-resident counters,
# optimizer evals/s + hypervolume-vs-budget) at the repo root.  CI uploads
# them as artifacts, so the repo accumulates a perf trajectory per commit.
#
# Usage: tools/bench.sh [--sweep-only|--opt-only]
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "bench.sh: cargo unavailable; skipping measurement run" >&2
    exit 0
fi

run_bench() {
    local bench="$1" out="$2"
    echo "==> cargo bench --bench $bench  (-> $out)"
    QAPPA_BENCH_JSON="$PWD/$out" cargo bench --bench "$bench"
    test -s "$out" || { echo "bench.sh: $out was not written" >&2; exit 1; }
}

mode="${1:-all}"
case "$mode" in
    --sweep-only) run_bench sweep_throughput BENCH_sweep.json ;;
    --opt-only)   run_bench opt_throughput BENCH_opt.json ;;
    all)
        run_bench sweep_throughput BENCH_sweep.json
        run_bench opt_throughput BENCH_opt.json
        ;;
    *) echo "bench.sh: unknown mode '$mode' (expected --sweep-only|--opt-only)" >&2; exit 2 ;;
esac

echo "OK: bench measurement artifacts written"
