#!/usr/bin/env bash
# Pre-PR gate: build, test, format and doc checks (referenced from README).
# Usage: tools/check.sh [--no-doc]
set -euo pipefail
cd "$(dirname "$0")/.."

run() {
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q
# Golden-snapshot gate: the 4 legacy PE presets must stay bit-identical to
# the checked-in expectations (tests/golden_presets.rs). Run explicitly so
# a drift is called out by name even when the full suite is skipped.
run cargo test -q golden
# SoA-vs-oracle equivalence gate: the memoized fast path must stay
# bit-identical to the legacy per-point evaluator (tests/integration_soa.rs,
# plus the cross-chunk/legacy-env determinism pins in integration_cli.rs).
# Run explicitly so a divergence is called out by name.
run cargo test -q --test integration_soa
# clippy/fmt/doc are advisory in environments without the components installed
if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy -q -- -D warnings
else
    echo "==> cargo clippy unavailable; skipping lint"
fi
if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --check
else
    echo "==> cargo fmt unavailable; skipping format check"
fi
if [ "${1:-}" != "--no-doc" ]; then
    run cargo doc --no-deps
fi

echo "OK: all checks passed"
