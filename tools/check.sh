#!/usr/bin/env bash
# Pre-PR gate: build, test, format and doc checks (referenced from README).
# Usage: tools/check.sh [--no-doc]
set -euo pipefail
cd "$(dirname "$0")/.."

run() {
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q
# Golden-snapshot gate: the 4 legacy PE presets must stay bit-identical to
# the checked-in expectations (tests/golden_presets.rs). Run explicitly so
# a drift is called out by name even when the full suite is skipped.
run cargo test -q golden
# SoA-vs-oracle equivalence gate: the memoized fast path must stay
# bit-identical to the legacy per-point evaluator (tests/integration_soa.rs,
# plus the cross-chunk/legacy-env determinism pins in integration_cli.rs).
# Run explicitly so a divergence is called out by name.
run cargo test -q --test integration_soa
# Golden 3-objective frontier snapshot: the seeded MobileNetV1
# latency/energy/accuracy frontier CSV (docs/ACCURACY.md) must stay
# byte-identical across runs on the same tree. Like the float PPA/DSE
# snapshots, this is a blessed snapshot — it self-seeds on a fresh
# checkout (first run writes tools/golden/opt_frontier_3obj.csv) and
# compares byte-exactly afterwards; delete the file to re-bless after an
# intentional change.
golden=tools/golden/opt_frontier_3obj.csv
tmp_out=$(mktemp -d)
run ./target/release/qappa optimize --workload mobilenetv1 --space tiny \
    --train 48 --budget 60 --pop 16 --backend native --seed 7 \
    --objectives latency,energy,accuracy --min-accuracy 0.9 \
    --out "$tmp_out" > /dev/null
if [ ! -f "$golden" ]; then
    mkdir -p "$(dirname "$golden")"
    cp "$tmp_out/optimize_frontier.csv" "$golden"
    echo "==> blessed new 3-objective frontier snapshot: $golden"
else
    run cmp "$golden" "$tmp_out/optimize_frontier.csv"
fi
rm -rf "$tmp_out"
# clippy/fmt/doc are advisory in environments without the components installed
if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy -q -- -D warnings
else
    echo "==> cargo clippy unavailable; skipping lint"
fi
if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --check
else
    echo "==> cargo fmt unavailable; skipping format check"
fi
if [ "${1:-}" != "--no-doc" ]; then
    run cargo doc --no-deps
fi

echo "OK: all checks passed"
