#!/usr/bin/env bash
# Pre-PR gate: build, test, format and doc checks (referenced from README).
# Usage: tools/check.sh [--no-doc]
set -euo pipefail
cd "$(dirname "$0")/.."

run() {
    echo "==> $*"
    "$@"
}

run cargo build --release
run cargo test -q
# clippy/fmt/doc are advisory in environments without the components installed
if cargo clippy --version >/dev/null 2>&1; then
    run cargo clippy -q -- -D warnings
else
    echo "==> cargo clippy unavailable; skipping lint"
fi
if cargo fmt --version >/dev/null 2>&1; then
    run cargo fmt --check
else
    echo "==> cargo fmt unavailable; skipping format check"
fi
if [ "${1:-}" != "--no-doc" ]; then
    run cargo doc --no-deps
fi

echo "OK: all checks passed"
