//! Calibration probe: per-PE-type decomposition of area / power / energy /
//! latency at representative configs, plus the headline ratios from clean
//! (jitter-free, oracle-direct) DSE — the tuning loop for DESIGN.md §5.
use qappa::config::*;
use qappa::synth::gates::GateLib;
use qappa::synth::pe::synthesize_pe;
use qappa::synth::array::synthesize_array;
use qappa::synth::oracle::*;
use qappa::dataflow::*;
use qappa::workloads;

fn main() {
    let lib = GateLib::freepdk45();
    let which = std::env::args().nth(1).unwrap_or_default();
    // representative "best" config per type (small spads, like the DSE picks)
    for ty in ALL_PE_TYPES {
        let mut cfg = AcceleratorConfig::default_with(ty);
        cfg.pe_rows = 24; cfg.pe_cols = 8; cfg.glb_kb = 108;
        cfg.spad_ifmap_b = 24; cfg.spad_filter_b = 112; cfg.spad_psum_b = 32;
        cfg.bandwidth_gbps = 8.0;
        let pe = synthesize_pe(&lib, &cfg);
        let arr = synthesize_array(&lib, &cfg);
        {
            use qappa::synth::array::*;
            let f = arr.fmax_mhz;
            let mac_nw = pe.energy_per_mac_fj(&lib) * arr.num_pes as f64 * f * REF_UTILIZATION;
            let wb = pe.pe_type.act_bits() as f64;
            let glb_nw = (arr.glb.access_energy_fj + WIRE_FJ_PER_BIT_MM*arr.avg_wire_mm*wb) * GLB_ACCESS_PER_MAC * arr.num_pes as f64 * f * REF_UTILIZATION;
            let infra_nw = lib.energy_per_op_fj(&arr.infra, 0.08) * f;
            let leak_nw = pe.leakage_nw(&lib)*arr.num_pes as f64 + arr.glb.leak_nw + lib.leakage_nw(&arr.infra);
            println!("  power: pe-array {:.1} + glb/noc {:.1} + infra {:.1} + leak {:.1} mW", mac_nw/1e6, glb_nw/1e6, infra_nw/1e6, leak_nw/1e6);
        }
        let ppa = synthesize_clean(&cfg);
        let ep = energy_params(&cfg);
        println!("\n=== {} (r24c8 g108 spads 24/112/32 bw8) ===", ty.label());
        println!("  PE: mac {:6.0} + spads {:6.0} + ctrl {:6.0} = {:6.0} um2; e/mac {:6.1} fJ (mac {:5.1} + spads {:5.1})",
            pe.mac.area_um2(&lib),
            pe.spad_ifmap.area_um2 + pe.spad_filter.area_um2 + pe.spad_psum.area_um2,
            lib.area_um2(&pe.ctrl),
            pe.area_um2(&lib),
            pe.energy_per_mac_fj(&lib),
            pe.mac.energy_per_mac_fj(&lib),
            pe.spad_ifmap.access_energy_fj + pe.spad_filter.access_energy_fj + 2.0*pe.spad_psum.access_energy_fj);
        println!("  chip: PEs {:5.3} + GLB {:5.3} + infra {:5.3} = {:5.3} mm2 | {:7.2} mW | fmax {:6.0} MHz",
            pe.area_um2(&lib) * arr.num_pes as f64 / 1e6 * 1.1,
            arr.glb.area_um2 / 1e6 * 1.1,
            lib.area_um2(&arr.infra) / 1e6 * 1.1,
            ppa.area_mm2, ppa.power_mw, ppa.fmax_mhz);
        for wl in ["vgg16", "resnet34"] {
            let layers = workloads::by_name(wl).unwrap();
            let cost = evaluate_network(&cfg, &ep, &layers);
            let compute: u64 = layers.iter().map(|l| map_layer(&cfg, &ep, l).compute_cycles).sum();
            println!("  {wl}: lat {:8.2} ms (compute-only {:8.2} ms), util {:4.2}, dram {:6.1} MB, energy(power*lat) {:7.2} mJ",
                cost.latency_s*1e3, compute as f64/(ep.fmax_mhz*1e3), cost.avg_utilization,
                cost.dram_bytes as f64/1e6, ppa.power_mw*cost.latency_s);
        }
    }
    if which == "dse" {
        // clean oracle-direct DSE ratios (no regression noise)
        use qappa::coordinator::*;
        use qappa::coordinator::explorer::*;
        use qappa::model::native::NativeBackend;
        let mut opts = DseOptions::default();
        opts.sigma = 0.0; opts.train_per_type = 512;
        let b = NativeBackend::new(7);
        for wl in ["vgg16", "resnet34", "resnet50"] {
            let layers = workloads::by_name(wl).unwrap();
            // run_dse returns a structured QappaError; keep the workload as
            // context instead of flattening the error to a bare string.
            let res = run_dse(&b, &layers, wl, &opts).unwrap_or_else(|e| {
                eprintln!("error: dse over {wl}: {e}");
                std::process::exit(1);
            });
            print!("{wl}: ");
            for ty in ALL_PE_TYPES {
                let (pa, e) = res.ratios[&ty];
                print!(" {}={:.2}x/{:.2}x", ty.label(), pa, e);
            }
            println!("\n   anchor {}", res.anchor.cfg.key());
            for ty in ALL_PE_TYPES {
                let best = res.points[&ty].iter().max_by(|a,b| a.perf_per_area.total_cmp(&b.perf_per_area)).unwrap();
                println!("   {} best: {} | thr {:8.2}/s area {:5.2} mm2 energy {:7.2} mJ fmax {:6.0}",
                    ty.label(), best.cfg.key(), best.throughput, best.ppa.area_mm2, best.energy_mj, best.ppa.fmax_mhz);
            }
        }
    }
}

#[allow(dead_code)]
fn power_breakdown() {}
