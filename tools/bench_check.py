#!/usr/bin/env python3
"""Perf-regression gate over the machine-readable bench artifacts.

`tools/bench.sh` emits BENCH_*.json documents ({"results": [...],
"metrics": {...}}, see rust/src/util/bench.rs).  This script compares the
`throughput_per_s` of every named bench result AND every gateable scalar
metric against the checked-in baseline (tools/bench_baseline.json) and
exits nonzero when anything regresses past the tolerance band.

    bench_check.py --check [opts] BENCH_sweep.json BENCH_opt.json BENCH_serve.json
    bench_check.py --bless [opts] BENCH_sweep.json BENCH_opt.json BENCH_serve.json

Metric direction is inferred from the name suffix:
  * `*_per_s`  -> higher is better (like result throughputs); regression
    when fresh < (1 - tolerance) * baseline
  * `*_ms`     -> lower is better (latency percentiles such as
    `serve/p99_ms`); regression when fresh > (1 + tolerance) * baseline
  * anything else (ratios, hypervolumes, hit rates) is informational:
    recorded when blessing, never gated.

`--check` semantics:
  * regression past the tolerance band   -> REGRESSION (exit 1)
  * better than baseline past the band   -> IMPROVED (pass; re-bless to
    ratchet the baseline forward)
  * bench missing from the baseline      -> NEW (pass with a notice; the
    bootstrap baseline is empty until someone blesses on stable hardware)
  * baseline entry missing from fresh    -> GONE (pass with a notice)

A human-readable comparison table is written to the report path (default
bench_check_report.txt) for CI to upload next to the raw JSON.

`--bless` rewrites the baseline from the given fresh artifacts.  Bless on
quiet, representative hardware only — the tolerance band absorbs runner
noise, not a laptop-vs-CI hardware gap.

Stdlib only; no third-party dependencies.
"""

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "bench_baseline.json")
DEFAULT_TOLERANCE = 0.30


def load_results(paths):
    """name -> throughput_per_s, merged across bench artifacts."""
    out = {}
    for path in paths:
        with open(path) as f:
            doc = json.load(f)
        for r in doc.get("results", []):
            name = r.get("name")
            thrpt = r.get("throughput_per_s")
            if name is None or thrpt is None:
                continue  # timing-only benches carry no throughput to gate
            out[name] = {"throughput_per_s": float(thrpt), "source": os.path.basename(path)}
    return out


def metric_direction(name):
    """'up' (higher is better), 'down' (lower is better), or None (info)."""
    if name.endswith("_per_s"):
        return "up"
    if name.endswith("_ms"):
        return "down"
    return None


def load_metrics(paths):
    """metric name -> value, merged across bench artifacts."""
    out = {}
    for path in paths:
        with open(path) as f:
            doc = json.load(f)
        metrics = doc.get("metrics", {})
        if not isinstance(metrics, dict):
            continue
        for name, value in metrics.items():
            if isinstance(value, (int, float)):
                out[name] = {"value": float(value), "source": os.path.basename(path)}
    return out


def load_baseline(path):
    if not os.path.exists(path):
        return {"tolerance": DEFAULT_TOLERANCE, "entries": {}}
    with open(path) as f:
        doc = json.load(f)
    doc.setdefault("tolerance", DEFAULT_TOLERANCE)
    doc.setdefault("entries", {})
    doc.setdefault("metrics", {})
    return doc


def bless(args):
    fresh = load_results(args.files)
    metrics = load_metrics(args.files)
    doc = {
        "comment": "Blessed bench numbers (tools/bench.sh --bless). The "
        "--check gate fails when a result throughput or a *_per_s metric "
        "drops more than `tolerance` below its entry here, or when a *_ms "
        "latency metric rises more than `tolerance` above it.",
        "tolerance": args.tolerance,
        "entries": dict(sorted(fresh.items())),
        "metrics": dict(sorted(metrics.items())),
    }
    with open(args.baseline, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(
        f"bench_check: blessed {len(fresh)} benches and {len(metrics)} "
        f"metrics -> {args.baseline}"
    )
    return 0


def check(args):
    fresh = load_results(args.files)
    fresh_metrics = load_metrics(args.files)
    baseline = load_baseline(args.baseline)
    tol = args.tolerance if args.tolerance is not None else baseline["tolerance"]
    entries = baseline["entries"]
    base_metrics = baseline["metrics"]

    rows = []
    failures = 0
    for name in sorted(set(fresh) | set(entries)):
        if name not in entries:
            rows.append((name, None, fresh[name]["throughput_per_s"], "NEW"))
            continue
        if name not in fresh:
            rows.append((name, entries[name]["throughput_per_s"], None, "GONE"))
            continue
        base = entries[name]["throughput_per_s"]
        now = fresh[name]["throughput_per_s"]
        ratio = now / base if base > 0 else float("inf")
        if ratio < 1.0 - tol:
            verdict = "REGRESSION"
            failures += 1
        elif ratio > 1.0 + tol:
            verdict = "IMPROVED"
        else:
            verdict = "ok"
        rows.append((name, base, now, verdict))

    for name in sorted(set(fresh_metrics) | set(base_metrics)):
        direction = metric_direction(name)
        if direction is None:
            if name in fresh_metrics:
                rows.append((name, None, fresh_metrics[name]["value"], "info"))
            continue
        if name not in base_metrics:
            rows.append((name, None, fresh_metrics[name]["value"], "NEW"))
            continue
        if name not in fresh_metrics:
            rows.append((name, base_metrics[name]["value"], None, "GONE"))
            continue
        base = base_metrics[name]["value"]
        now = fresh_metrics[name]["value"]
        ratio = now / base if base > 0 else float("inf")
        worse = ratio < 1.0 - tol if direction == "up" else ratio > 1.0 + tol
        better = ratio > 1.0 + tol if direction == "up" else ratio < 1.0 - tol
        if worse:
            verdict = "REGRESSION"
            failures += 1
        elif better:
            verdict = "IMPROVED"
        else:
            verdict = "ok"
        rows.append((name, base, now, verdict))

    lines = [f"bench_check: tolerance ±{tol:.0%}, baseline {args.baseline}"]
    lines.append(f"{'bench':<44} {'baseline':>12} {'fresh':>12} {'ratio':>7}  verdict")
    for name, base, now, verdict in rows:
        b = f"{base:.1f}" if base is not None else "-"
        n = f"{now:.1f}" if now is not None else "-"
        r = f"{now / base:.2f}x" if base and now else "-"
        lines.append(f"{name:<44} {b:>12} {n:>12} {r:>7}  {verdict}")
    if not rows:
        lines.append("(no throughput-bearing bench results found)")
    if not entries:
        lines.append(
            "baseline is empty (bootstrap): run `tools/bench.sh --bless` on "
            "representative hardware to arm the gate"
        )
    report = "\n".join(lines) + "\n"
    sys.stdout.write(report)
    if args.report:
        with open(args.report, "w") as f:
            f.write(report)

    if failures:
        print(
            f"bench_check: FAIL — {failures} bench(es) regressed past "
            f"{tol:.0%}; if intentional, re-bless with tools/bench.sh --bless",
            file=sys.stderr,
        )
        return 1
    print("bench_check: OK")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--check", action="store_true", help="gate fresh results against the baseline")
    mode.add_argument("--bless", action="store_true", help="rewrite the baseline from fresh results")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument(
        "--tolerance",
        type=float,
        default=None,
        help=f"allowed fractional drop (default: baseline file's, else {DEFAULT_TOLERANCE})",
    )
    ap.add_argument("--report", default="bench_check_report.txt", help="comparison report path ('' to skip)")
    ap.add_argument("files", nargs="+", help="BENCH_*.json artifacts to read")
    args = ap.parse_args()
    if args.bless and args.tolerance is None:
        args.tolerance = DEFAULT_TOLERANCE
    sys.exit(bless(args) if args.bless else check(args))


if __name__ == "__main__":
    main()
