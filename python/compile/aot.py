"""AOT compile path: lower the L2 model functions to HLO *text* artifacts.

HLO text — NOT ``lowered.compile()`` or serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids that
the rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Emits, for each polynomial degree in {1, 2, 3}:

* ``predict_d{d}.hlo.txt`` — (X[B,D], W[P,3])           -> (Yhat[B,3],)
* ``fit_d{d}.hlo.txt``     — (X[N,D], Y[N,3], w[N], λ[]) -> (W[P,3],)
* ``loss_d{d}.hlo.txt``    — (X[N,D], Y[N,3], w[N], W[P,3]) -> (mse[3],)

plus ``manifest.json`` describing every artifact's shapes and the feature
ordering contract, which ``rust/src/runtime/artifact.rs`` consumes.

Python runs exactly once (``make artifacts``); the rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import poly

# Fixed-shape contract shared with the rust runtime (see DESIGN.md §3).
D = poly.DEFAULT_D          # design-space feature dimension
M = 3                       # targets: [power_mW, fmax_MHz, area_mm2]
N_FIT = 2048                # fit/loss row capacity (padding masked by w=0)
B_PREDICT = 256             # predict batch tile
B_GRAM = 256                # gram accumulation tile (Grams are additive,
                            # so the rust engine chunks arbitrary row
                            # counts through this tile)
DEGREES = (1, 2, 3)

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default elides arrays >10 elements as
    # literal "{...}", which the HLO text parser silently turns into
    # garbage — the baked monomial index vectors MUST round-trip.
    return comp.as_hlo_text(print_large_constants=True)


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def lower_all() -> dict[str, str]:
    """Lower every artifact; returns {filename: hlo_text}."""
    out: dict[str, str] = {}
    for d in DEGREES:
        predict = lambda x, w, _d=d: (model.predict_fn(x, w, _d),)
        fit = lambda x, y, w, lam, _d=d: (model.fit_fn(x, y, w, lam, _d),)
        loss = lambda x, y, w, coef, _d=d: (model.loss_fn(x, y, w, coef, _d),)
        gram = lambda x, y, w, _d=d: model.gram_fn(x, y, w, _d)
        solve = lambda g, c, n, lam: (model.solve_fn(g, c, n, lam),)
        p = poly.num_features(D, d)

        out[f"predict_d{d}.hlo.txt"] = to_hlo_text(
            jax.jit(predict).lower(_spec(B_PREDICT, D), _spec(p, M)))
        out[f"fit_d{d}.hlo.txt"] = to_hlo_text(
            jax.jit(fit).lower(_spec(N_FIT, D), _spec(N_FIT, M),
                               _spec(N_FIT), _spec()))
        out[f"loss_d{d}.hlo.txt"] = to_hlo_text(
            jax.jit(loss).lower(_spec(N_FIT, D), _spec(N_FIT, M),
                                _spec(N_FIT), _spec(p, M)))
        # CV fast path: per-fold Gram accumulation + cheap per-lambda solve
        out[f"gram_d{d}.hlo.txt"] = to_hlo_text(
            jax.jit(gram).lower(_spec(B_GRAM, D), _spec(B_GRAM, M),
                                _spec(B_GRAM)))
        out[f"solve_d{d}.hlo.txt"] = to_hlo_text(
            jax.jit(solve).lower(_spec(p, p), _spec(p, M), _spec(), _spec()))
    return out


def manifest() -> dict:
    arts = {}
    for d in DEGREES:
        p = poly.num_features(D, d)
        arts[f"predict_d{d}"] = {
            "file": f"predict_d{d}.hlo.txt", "degree": d, "p": p,
            "inputs": [["x", [B_PREDICT, D]], ["w", [p, M]]],
            "outputs": [["yhat", [B_PREDICT, M]]],
        }
        arts[f"fit_d{d}"] = {
            "file": f"fit_d{d}.hlo.txt", "degree": d, "p": p,
            "inputs": [["x", [N_FIT, D]], ["y", [N_FIT, M]],
                       ["w", [N_FIT]], ["lam", []]],
            "outputs": [["coef", [p, M]]],
        }
        arts[f"loss_d{d}"] = {
            "file": f"loss_d{d}.hlo.txt", "degree": d, "p": p,
            "inputs": [["x", [N_FIT, D]], ["y", [N_FIT, M]],
                       ["w", [N_FIT]], ["coef", [p, M]]],
            "outputs": [["mse", [M]]],
        }
        arts[f"gram_d{d}"] = {
            "file": f"gram_d{d}.hlo.txt", "degree": d, "p": p,
            "inputs": [["x", [B_GRAM, D]], ["y", [B_GRAM, M]], ["w", [B_GRAM]]],
            "outputs": [["g", [p, p]], ["c", [p, M]], ["n_eff", []]],
        }
        arts[f"solve_d{d}"] = {
            "file": f"solve_d{d}.hlo.txt", "degree": d, "p": p,
            "inputs": [["g", [p, p]], ["c", [p, M]], ["n_eff", []], ["lam", []]],
            "outputs": [["coef", [p, M]]],
        }
    return {
        "version": 1,
        "d": D,
        "m": M,
        "n_fit": N_FIT,
        "b_predict": B_PREDICT,
        "b_gram": B_GRAM,
        "degrees": list(DEGREES),
        "feature_order": [
            "pe_rows", "pe_cols", "glb_kb",
            "spad_ifmap_b", "spad_filter_b", "spad_psum_b", "bandwidth_gbps",
        ],
        "target_order": ["power_mw", "fmax_mhz", "area_mm2"],
        "monomials": {
            str(d): [list(t) for t in poly.monomial_indices(D, d)]
            for d in DEGREES
        },
        "artifacts": arts,
    }


def golden() -> dict:
    """Deterministic test vectors for the rust runtime's integration tests.

    For each degree: a predict case (full B tile) and a fit case (padded to
    N_FIT with w=0) with expected outputs computed by the in-process L2
    functions — the rust side must reproduce them through the artifacts.
    """
    import numpy as np

    out: dict = {"cases": {}}
    for d in DEGREES:
        rng = np.random.default_rng(1000 + d)
        p = poly.num_features(D, d)
        x = rng.uniform(-1.5, 1.5, (B_PREDICT, D)).astype(np.float32)
        w = (rng.standard_normal((p, M)) * 0.5).astype(np.float32)
        yhat = np.asarray(model.predict_fn(jnp.asarray(x), jnp.asarray(w), d))

        n_real = 384
        fx = np.zeros((N_FIT, D), np.float32)
        fy = np.zeros((N_FIT, M), np.float32)
        fw = np.zeros((N_FIT,), np.float32)
        fx[:n_real] = rng.uniform(-1, 1, (n_real, D))
        fy[:n_real] = rng.standard_normal((n_real, M))
        fw[:n_real] = 1.0
        lam = 0.01
        coef = np.asarray(model.fit_fn(jnp.asarray(fx), jnp.asarray(fy),
                                       jnp.asarray(fw), jnp.float32(lam), d))
        mse = np.asarray(model.loss_fn(jnp.asarray(fx), jnp.asarray(fy),
                                       jnp.asarray(fw), jnp.asarray(coef), d))
        out["cases"][str(d)] = {
            "predict": {
                "x": x.ravel().tolist(),
                "w": w.ravel().tolist(),
                "yhat": yhat.ravel().tolist(),
            },
            "fit": {
                "n_real": n_real,
                "x": fx[:n_real].ravel().tolist(),
                "y": fy[:n_real].ravel().tolist(),
                "lam": lam,
                "coef": coef.ravel().tolist(),
                "mse": mse.ravel().tolist(),
            },
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the stamp artifact; siblings are emitted "
                         "into its directory")
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    texts = lower_all()
    for name, text in sorted(texts.items()):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    man = manifest()
    man["hlo_sha256"] = {
        name: hashlib.sha256(text.encode()).hexdigest()[:16]
        for name, text in texts.items()
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(man, f, indent=1, sort_keys=True)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')}")

    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden(), f)
    print(f"wrote {os.path.join(out_dir, 'golden.json')}")

    # Makefile stamp target: make's freshness check keys on this file.
    with open(os.path.join(out_dir, "model.hlo.txt"), "w") as f:
        f.write("".join(f"{n}\n" for n in sorted(texts)))
    print(f"stamped {args.out}")


if __name__ == "__main__":
    main()
