"""Layer-2 JAX model: weighted polynomial ridge regression for PPA fitting.

This is the compute graph the rust coordinator drives via the AOT artifacts:

* ``fit_fn``     — normal-equation ridge solve (Gram via the L1 Pallas kernel,
                   Cholesky factorization/solve hand-rolled with ``fori_loop``
                   so the lowered HLO contains NO LAPACK custom calls — the
                   PJRT CPU client used from rust cannot resolve them).
* ``predict_fn`` — fused polynomial evaluation (L1 Pallas kernel).
* ``loss_fn``    — weighted per-output MSE on a held-out (masked) set; used
                   by the rust side's k-fold cross-validation loop.

Fixed-shape contract (HLO is static): the rust side pads the row dimension
and masks padding with ``w = 0``.  Fold selection in k-fold CV is likewise a
0/1 weight vector, so a single fit artifact serves every fold.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import poly


# ---------------------------------------------------------------------------
# LAPACK-free linear algebra (lowered into the AOT artifacts)
# ---------------------------------------------------------------------------


def cholesky(a: jax.Array) -> jax.Array:
    """Lower-triangular Cholesky factor via column-wise Banachiewicz.

    Pure ``fori_loop`` + vector ops: lowers to a plain HLO while-loop with
    dynamic-update-slice — runs on any PJRT backend.
    """
    p = a.shape[0]
    rng = jnp.arange(p)

    def body(j, l):
        lt = (rng < j).astype(a.dtype)          # columns strictly left of j
        row_j = l[j] * lt                        # [P] — L[j, :j]
        s = l @ row_j                            # s_i = sum_{k<j} L[i,k] L[j,k]
        d = jnp.sqrt(jnp.maximum(a[j, j] - s[j], 1e-30))
        col = (a[:, j] - s) / d
        col = jnp.where(rng > j, col, 0.0).at[j].set(d)
        return l.at[:, j].set(col)

    return lax.fori_loop(0, p, body, jnp.zeros_like(a))


def solve_lower(l: jax.Array, b: jax.Array) -> jax.Array:
    """Forward substitution: solve L z = b for lower-triangular L; b [P, M]."""
    p = l.shape[0]

    def body(i, z):
        zi = (b[i] - l[i] @ z) / l[i, i]
        return z.at[i].set(zi)

    return lax.fori_loop(0, p, body, jnp.zeros_like(b))


def solve_upper(u: jax.Array, b: jax.Array) -> jax.Array:
    """Back substitution: solve U z = b for upper-triangular U; b [P, M]."""
    p = u.shape[0]

    def body(k, z):
        i = p - 1 - k
        zi = (b[i] - u[i] @ z) / u[i, i]
        return z.at[i].set(zi)

    return lax.fori_loop(0, p, body, jnp.zeros_like(b))


def cholesky_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """Solve the SPD system A X = B via Cholesky (no LAPACK)."""
    l = cholesky(a)
    return solve_upper(l.T, solve_lower(l, b))


# ---------------------------------------------------------------------------
# Model functions (traced into artifacts by aot.py)
# ---------------------------------------------------------------------------


def gram_fn(x: jax.Array, y: jax.Array, w: jax.Array,
            degree: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Weighted normal-equation accumulators (un-normalized).

    Returns ``(G, C, n_eff)`` with ``G = F' diag(w) F``,
    ``C = F' diag(w) y`` and ``n_eff = sum(w)``.  Grams are *additive* over
    row subsets, which is what makes the k-fold CV fast path possible: the
    rust coordinator computes one Gram per fold and assembles every
    training split by subtraction instead of re-reducing all N rows.
    """
    g, c = poly.gram(x, y, w, degree, block=poly.auto_block(x.shape[0]))
    return g, c, jnp.sum(w)


def solve_fn(g: jax.Array, c: jax.Array, n_eff: jax.Array,
             lam: jax.Array) -> jax.Array:
    """Ridge solve from accumulated Grams: returns W [P, M].

    Solves ``(G / n_eff + lam * Pen) W = C / n_eff`` where ``Pen`` excludes
    the intercept from the penalty.
    """
    n_eff = jnp.maximum(n_eff, 1.0)
    p = g.shape[0]
    pen = jnp.ones((p,), g.dtype).at[0].set(0.0)
    a = g / n_eff + lam * jnp.diag(pen)
    # Tiny jitter keeps the factorization stable when lam -> 0 and the
    # degree-3 Gram is near-singular on small folds.
    a = a + 1e-7 * jnp.eye(p, dtype=g.dtype)
    return cholesky_solve(a, c / n_eff)


def fit_fn(x: jax.Array, y: jax.Array, w: jax.Array, lam: jax.Array,
           degree: int) -> jax.Array:
    """Weighted ridge fit: returns coefficients W [P, M].

    ``solve_fn(*gram_fn(...))`` — rows with ``w = 0`` (padding, held-out
    folds) do not influence the fit.
    """
    g, c, n_eff = gram_fn(x, y, w, degree)
    return solve_fn(g, c, n_eff, lam)


def predict_fn(x: jax.Array, coef: jax.Array, degree: int) -> jax.Array:
    """Batched model evaluation: [B, D], [P, M] -> [B, M]."""
    return poly.predict(x, coef, degree, block=poly.auto_block(x.shape[0]))


def loss_fn(x: jax.Array, y: jax.Array, w: jax.Array, coef: jax.Array,
            degree: int) -> jax.Array:
    """Weighted per-output MSE [M] over the rows selected by ``w``."""
    err = poly.predict(x, coef, degree, block=poly.auto_block(x.shape[0])) - y
    n_eff = jnp.maximum(jnp.sum(w), 1.0)
    return jnp.sum(w[:, None] * err * err, axis=0) / n_eff
