"""Layer-1 Pallas kernels for QAPPA's polynomial PPA models.

Three kernels, all blocked over the design-point (row) dimension so each
block's working set fits VMEM on a real TPU (see DESIGN.md §4):

* ``polyfeat``  — X[B, D]          -> F[B, P]  monomial feature expansion
* ``predict``   — X[B, D], W[P, M] -> Y[B, M]  fused expansion + matmul (MXU)
* ``gram``      — X[N, D], y[N, M], w[N] -> (G[P, P], C[P, M]) weighted
                  normal-equation accumulators  G = F' diag(w) F,
                  C = F' diag(w) y, accumulated block-by-block in VMEM.

All kernels are lowered with ``interpret=True``: the CPU PJRT client that the
rust coordinator embeds cannot execute Mosaic custom calls.  On a real TPU the
same BlockSpecs map the expansion to the VPU and the two matmuls to the MXU.

The monomial index sets are a property of (D, degree), not data; Pallas does
not allow kernels to close over constant arrays, so they are fed as small
int32 operands (one gather-index vector per monomial degree x position) that
constant-fold into the AOT artifact.  The expansion itself is a handful of
gathers and elementwise multiplies — no dynamic control flow on the hot path.
"""

from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Feature dimension used by the shipped artifacts: [pe_rows, pe_cols, glb_kb,
# spad_ifmap, spad_filter, spad_psum, bandwidth].  Kept symbolic everywhere so
# the kernels (and tests) work for any D.
DEFAULT_D = 7

# Row-block size: 128 rows x 120 features (degree 3) of f32 is ~60 KiB of
# VMEM for the feature tile — small enough to double-buffer.
DEFAULT_BLOCK = 128


def monomial_indices(d: int, degree: int) -> list[tuple[int, ...]]:
    """All monomials of total degree 1..``degree`` over ``d`` variables.

    Returned in a canonical order (degree-major, then lexicographic index
    tuples with repetition).  The constant term is *not* included here; the
    feature matrix is ``[1, monomials...]`` so ``P = 1 + len(indices)``.
    """
    if d <= 0:
        raise ValueError(f"d must be positive, got {d}")
    if degree < 1:
        raise ValueError(f"degree must be >= 1, got {degree}")
    out: list[tuple[int, ...]] = []
    for k in range(1, degree + 1):
        out.extend(itertools.combinations_with_replacement(range(d), k))
    return out


def num_features(d: int, degree: int) -> int:
    """P — number of polynomial features including the constant column."""
    return 1 + len(monomial_indices(d, degree))


def _gather_plan(d: int, degree: int):
    """Group monomials by degree k into gather-index vectors.

    Returns ``(meta, arrays)`` where ``meta`` is ``[(k, n_k), ...]`` (static,
    baked into the kernel) and ``arrays`` is the flat list of int32 index
    vectors (length k per group) passed as kernel operands.
    """
    by_deg: dict[int, list[tuple[int, ...]]] = {}
    for t in monomial_indices(d, degree):
        by_deg.setdefault(len(t), []).append(t)
    meta: list[tuple[int, int]] = []
    arrays: list[np.ndarray] = []
    for k in sorted(by_deg):
        tuples = by_deg[k]
        meta.append((k, len(tuples)))
        for pos in range(k):
            arrays.append(np.asarray([t[pos] for t in tuples], np.int32))
    return meta, arrays


def _expand_block(x: jax.Array, idx_refs, meta) -> jax.Array:
    """Expand a [b, D] block into [b, P] monomial features.

    Gathers are grouped by monomial degree so each degree is one ``take`` per
    operand position followed by elementwise products — VPU-friendly.
    """
    b = x.shape[0]
    cols = [jnp.ones((b, 1), x.dtype)]
    it = iter(idx_refs)
    for k, _n_k in meta:
        prod = None
        for _pos in range(k):
            # mode='clip': indices are static and always in-bounds; the
            # default 'fill' mode wraps the gather in an out-of-bounds ->
            # NaN select whose shared callee miscompiles through the HLO
            # text round-trip (xla_extension 0.5.1 text parser).
            g = jnp.take(x, next(it)[...], axis=1, mode="clip")
            prod = g if prod is None else prod * g
        cols.append(prod)
    return jnp.concatenate(cols, axis=1)


def _idx_specs(meta):
    specs = []
    for k, n_k in meta:
        specs.extend([pl.BlockSpec((n_k,), lambda i: (0,))] * k)
    return specs


def _check_block(total: int, block: int, what: str) -> int:
    block = min(block, total)
    if total % block:
        raise ValueError(f"{what}={total} not a multiple of block={block}")
    return block


def auto_block(total: int, block: int = DEFAULT_BLOCK) -> int:
    """Largest divisor of ``total`` that is <= ``block``.

    The AOT artifacts use shapes that are multiples of DEFAULT_BLOCK; this
    helper lets the L2 model functions accept arbitrary row counts in tests.
    """
    block = min(block, total)
    while total % block:
        block -= 1
    return block


# ---------------------------------------------------------------------------
# polyfeat
# ---------------------------------------------------------------------------


def _polyfeat_kernel(x_ref, *refs, meta):
    f_ref = refs[-1]
    f_ref[...] = _expand_block(x_ref[...], refs[:-1], meta)


def polyfeat(x: jax.Array, degree: int, block: int = DEFAULT_BLOCK) -> jax.Array:
    """Pallas polynomial feature expansion: [B, D] -> [B, P].

    ``B`` must be a multiple of ``block`` (the AOT wrapper pads; tests sweep
    odd sizes through ``block=B``).
    """
    b_total, d = x.shape
    block = _check_block(b_total, block, "B")
    meta, arrays = _gather_plan(d, degree)
    p = num_features(d, degree)
    return pl.pallas_call(
        functools.partial(_polyfeat_kernel, meta=meta),
        grid=(b_total // block,),
        in_specs=[pl.BlockSpec((block, d), lambda i: (i, 0)), *_idx_specs(meta)],
        out_specs=pl.BlockSpec((block, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_total, p), x.dtype),
        interpret=True,
    )(x, *arrays)


# ---------------------------------------------------------------------------
# predict (fused expansion + matmul)
# ---------------------------------------------------------------------------


def _predict_kernel(x_ref, *refs, meta):
    w_ref, y_ref = refs[-2], refs[-1]
    f = _expand_block(x_ref[...], refs[:-2], meta)
    # [b, P] @ [P, M] — the MXU op on real hardware.
    y_ref[...] = jnp.dot(f, w_ref[...], preferred_element_type=jnp.float32)


def predict(x: jax.Array, w: jax.Array, degree: int,
            block: int = DEFAULT_BLOCK) -> jax.Array:
    """Fused polynomial model evaluation: [B, D], [P, M] -> [B, M]."""
    b_total, d = x.shape
    block = _check_block(b_total, block, "B")
    meta, arrays = _gather_plan(d, degree)
    p = num_features(d, degree)
    if w.shape[0] != p:
        raise ValueError(f"W has {w.shape[0]} rows, expected P={p}")
    m = w.shape[1]
    return pl.pallas_call(
        functools.partial(_predict_kernel, meta=meta),
        grid=(b_total // block,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            *_idx_specs(meta),
            pl.BlockSpec((p, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_total, m), x.dtype),
        interpret=True,
    )(x, *arrays, w)


# ---------------------------------------------------------------------------
# gram (weighted normal-equation accumulators)
# ---------------------------------------------------------------------------


def _gram_kernel(x_ref, y_ref, w_ref, *refs, meta):
    g_ref, c_ref = refs[-2], refs[-1]
    i = pl.program_id(0)
    f = _expand_block(x_ref[...], refs[:-2], meta)
    fw = f * w_ref[...][:, None]

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    g_ref[...] += jnp.dot(fw.T, f, preferred_element_type=jnp.float32)
    c_ref[...] += jnp.dot(fw.T, y_ref[...], preferred_element_type=jnp.float32)


def gram(x: jax.Array, y: jax.Array, w: jax.Array, degree: int,
         block: int = DEFAULT_BLOCK) -> tuple[jax.Array, jax.Array]:
    """Blocked weighted Gram accumulation.

    Returns ``G = F' diag(w) F`` ([P, P]) and ``C = F' diag(w) y`` ([P, M]).
    The G/C output blocks revisit the same VMEM tile across the whole grid,
    so the accumulation never leaves VMEM on real hardware.
    """
    n_total, d = x.shape
    block = _check_block(n_total, block, "N")
    meta, arrays = _gather_plan(d, degree)
    p = num_features(d, degree)
    m = y.shape[1]
    return pl.pallas_call(
        functools.partial(_gram_kernel, meta=meta),
        grid=(n_total // block,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block, m), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            *_idx_specs(meta),
        ],
        out_specs=[
            pl.BlockSpec((p, p), lambda i: (0, 0)),
            pl.BlockSpec((p, m), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, p), x.dtype),
            jax.ShapeDtypeStruct((p, m), x.dtype),
        ],
        interpret=True,
    )(x, y, w, *arrays)
