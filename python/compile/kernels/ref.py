"""Pure-jnp oracle for the Pallas kernels (no pallas, no shared helpers).

Deliberately written with naive per-monomial loops so it cannot share a bug
with the vectorized kernel implementations in ``poly.py``.
"""

from __future__ import annotations

import itertools

import jax.numpy as jnp


def monomial_indices_ref(d: int, degree: int) -> list[tuple[int, ...]]:
    out: list[tuple[int, ...]] = []
    for k in range(1, degree + 1):
        out.extend(itertools.combinations_with_replacement(range(d), k))
    return out


def polyfeat_ref(x: jnp.ndarray, degree: int) -> jnp.ndarray:
    """[B, D] -> [B, P]: naive column-by-column monomial expansion."""
    b, d = x.shape
    cols = [jnp.ones((b,), x.dtype)]
    for tup in monomial_indices_ref(d, degree):
        col = jnp.ones((b,), x.dtype)
        for j in tup:
            col = col * x[:, j]
        cols.append(col)
    return jnp.stack(cols, axis=1)


def predict_ref(x: jnp.ndarray, w: jnp.ndarray, degree: int) -> jnp.ndarray:
    return polyfeat_ref(x, degree) @ w


def gram_ref(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
             degree: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    f = polyfeat_ref(x, degree)
    fw = f * w[:, None]
    return fw.T @ f, fw.T @ y


def ridge_fit_ref(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
                  lam: float, degree: int) -> jnp.ndarray:
    """Reference weighted ridge solve using jnp.linalg (LAPACK is fine in
    pytest — it is only the AOT path that must avoid custom calls)."""
    g, c = gram_ref(x, y, w, degree)
    n_eff = jnp.maximum(jnp.sum(w), 1.0)
    p = g.shape[0]
    # Intercept (feature 0) is not penalized.
    pen = jnp.ones((p,)).at[0].set(0.0)
    a = g / n_eff + lam * jnp.diag(pen)
    return jnp.linalg.solve(a, c / n_eff)


def mse_ref(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray,
            coef: jnp.ndarray, degree: int) -> jnp.ndarray:
    """Weighted per-output MSE, matching model.loss_fn's contract."""
    err = predict_ref(x, coef, degree) - y
    n_eff = jnp.maximum(jnp.sum(w), 1.0)
    return jnp.sum(w[:, None] * err * err, axis=0) / n_eff
