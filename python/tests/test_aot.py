"""AOT path tests: artifacts lower to clean HLO text and execute correctly
through the same xla_client PJRT interface the rust runtime wraps."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import poly, ref

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def texts():
    return aot.lower_all()


def test_all_artifacts_emitted(texts):
    names = {f"{kind}_d{d}.hlo.txt"
             for kind in ("predict", "fit", "loss", "gram", "solve")
             for d in aot.DEGREES}
    assert set(texts) == names


def test_hlo_text_has_no_custom_calls(texts):
    """The PJRT CPU client in rust cannot resolve LAPACK/Mosaic custom calls;
    the hand-rolled Cholesky must keep the HLO free of them."""
    for name, text in texts.items():
        assert "custom-call" not in text, f"custom call leaked into {name}"


def test_hlo_entry_is_tuple(texts):
    for name, text in texts.items():
        assert "ENTRY" in text, name


def test_manifest_consistency():
    man = aot.manifest()
    assert man["d"] == poly.DEFAULT_D
    assert man["degrees"] == list(aot.DEGREES)
    assert len(man["feature_order"]) == man["d"]
    assert len(man["target_order"]) == man["m"]
    for d in aot.DEGREES:
        p = poly.num_features(man["d"], d)
        assert man["artifacts"][f"predict_d{d}"]["p"] == p
        mons = man["monomials"][str(d)]
        assert len(mons) == p - 1
        assert mons == [list(t) for t in poly.monomial_indices(man["d"], d)]
    # manifest must be JSON-serializable (rust parses it)
    json.dumps(man)


def _run_hlo(text: str, args):
    """Execute artifact HLO *text* end-to-end — the same parse-and-compile
    path the rust runtime uses (text -> HloModuleProto -> compile)."""
    import jax._src.interpreters.mlir as jmlir
    from jax._src.lib import xla_client as xc
    from jax._src.lib.mlir import ir
    from jaxlib._jax import DeviceList

    m = xc._xla.hlo_module_from_text(text)
    comp = xc.XlaComputation(m.as_serialized_hlo_module_proto())
    mlir_text = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    client = xc.make_cpu_client()
    with jmlir.make_ir_context():
        mod = ir.Module.parse(mlir_text)
        devs = DeviceList(tuple(client.local_devices()))
        exe = client.compile_and_load(mod, devs) \
            if hasattr(client, "compile_and_load") else client.compile(mod, devs)
        bufs = [client.buffer_from_pyval(np.asarray(a)) for a in args]
        out = exe.execute(bufs)
    return [np.asarray(o) for o in out]


@pytest.mark.parametrize("degree", aot.DEGREES)
def test_predict_artifact_numerics(texts, degree):
    rng = np.random.default_rng(degree)
    p = poly.num_features(aot.D, degree)
    x = rng.uniform(-1, 1, (aot.B_PREDICT, aot.D)).astype(np.float32)
    w = rng.standard_normal((p, aot.M)).astype(np.float32)
    try:
        (got,) = _run_hlo(texts[f"predict_d{degree}.hlo.txt"], [x, w])
    except Exception as e:  # pragma: no cover - API drift guard
        pytest.skip(f"xla_client direct-HLO execution unavailable: {e}")
    want = np.asarray(ref.predict_ref(jnp.asarray(x), jnp.asarray(w), degree))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("degree", (1, 2))
def test_fit_artifact_numerics(texts, degree):
    """fit artifact == in-process fit_fn on padded data."""
    rng = np.random.default_rng(10 + degree)
    n_real = 300
    x = np.zeros((aot.N_FIT, aot.D), np.float32)
    y = np.zeros((aot.N_FIT, aot.M), np.float32)
    w = np.zeros((aot.N_FIT,), np.float32)
    x[:n_real] = rng.uniform(-1, 1, (n_real, aot.D))
    y[:n_real] = rng.standard_normal((n_real, aot.M))
    w[:n_real] = 1.0
    lam = np.float32(0.01)
    try:
        (got,) = _run_hlo(texts[f"fit_d{degree}.hlo.txt"], [x, y, w, lam])
    except Exception as e:  # pragma: no cover
        pytest.skip(f"xla_client direct-HLO execution unavailable: {e}")
    want = np.asarray(model.fit_fn(jnp.asarray(x), jnp.asarray(y),
                                   jnp.asarray(w), jnp.asarray(lam), degree))
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)
