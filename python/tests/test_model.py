"""L2 model tests: LAPACK-free linear algebra + ridge fit behaviour."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile import model
from compile.kernels import poly, ref

jax.config.update("jax_enable_x64", False)

COMMON = dict(deadline=None, max_examples=20,
              suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _spd(rng, p, cond=10.0):
    """Random well-conditioned SPD matrix."""
    q, _ = np.linalg.qr(rng.standard_normal((p, p)))
    eig = np.linspace(1.0, cond, p)
    return (q * eig) @ q.T


# ---------------------------------------------------------------------------
# Cholesky + triangular solves (the hand-rolled, scan-based linalg)
# ---------------------------------------------------------------------------


@given(p=st.integers(1, 24), seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_cholesky_matches_numpy(p, seed):
    a = _spd(np.random.default_rng(seed), p).astype(np.float32)
    l = np.asarray(model.cholesky(jnp.asarray(a)))
    want = np.linalg.cholesky(a.astype(np.float64))
    np.testing.assert_allclose(l, want, rtol=2e-3, atol=2e-3)
    # strictly lower-triangular output
    assert np.allclose(np.triu(l, 1), 0.0)


@given(p=st.integers(1, 24), m=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_cholesky_solve_roundtrip(p, m, seed):
    rng = np.random.default_rng(seed)
    a = _spd(rng, p).astype(np.float32)
    b = rng.standard_normal((p, m)).astype(np.float32)
    x = np.asarray(model.cholesky_solve(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(a @ x, b, rtol=5e-3, atol=5e-3)


def test_solve_lower_upper_unit():
    l = jnp.asarray([[2.0, 0.0], [1.0, 3.0]], jnp.float32)
    b = jnp.asarray([[4.0], [11.0]], jnp.float32)
    z = model.solve_lower(l, b)
    np.testing.assert_allclose(z, [[2.0], [3.0]], rtol=1e-6)
    u = l.T
    z2 = model.solve_upper(u, jnp.asarray([[7.0], [9.0]], jnp.float32))
    np.testing.assert_allclose(u @ z2, [[7.0], [9.0]], rtol=1e-5)


# ---------------------------------------------------------------------------
# fit / loss
# ---------------------------------------------------------------------------


def test_fit_recovers_planted_polynomial():
    """fit_fn must recover coefficients of an exactly-polynomial target."""
    rng = np.random.default_rng(0)
    n, d, degree = 256, 4, 2
    p = poly.num_features(d, degree)
    x = jnp.asarray(rng.uniform(-1, 1, (n, d)).astype(np.float32))
    coef_true = jnp.asarray(rng.standard_normal((p, 3)).astype(np.float32))
    y = ref.predict_ref(x, coef_true, degree)
    w = jnp.ones((n,), jnp.float32)
    coef = model.fit_fn(x, y, w, jnp.float32(0.0), degree)
    np.testing.assert_allclose(coef, coef_true, rtol=5e-2, atol=5e-3)
    mse = model.loss_fn(x, y, w, coef, degree)
    assert float(jnp.max(mse)) < 1e-5


def test_fit_matches_lapack_reference():
    rng = np.random.default_rng(1)
    n, d, degree = 200, 7, 2
    x = jnp.asarray(rng.uniform(-1, 1, (n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.2, 1, n).astype(np.float32))
    lam = 0.01
    got = model.fit_fn(x, y, w, jnp.float32(lam), degree)
    want = ref.ridge_fit_ref(x, y, w, lam, degree)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_fit_ignores_zero_weight_rows():
    rng = np.random.default_rng(2)
    n, d, degree = 128, 5, 2
    x = jnp.asarray(rng.uniform(-1, 1, (n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    w_full = jnp.concatenate([jnp.ones(96), jnp.zeros(32)]).astype(jnp.float32)
    a = model.fit_fn(x, y, w_full, jnp.float32(0.1), degree)
    # corrupt the masked rows wildly — the fit must not move
    y2 = y.at[96:].set(1e3)
    x2 = x.at[96:].set(0.5)
    b = model.fit_fn(x2, y2, w_full, jnp.float32(0.1), degree)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_ridge_regularization_shrinks_coefficients():
    rng = np.random.default_rng(3)
    n, d, degree = 128, 7, 3
    x = jnp.asarray(rng.uniform(-1, 1, (n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    w = jnp.ones((n,), jnp.float32)
    small = model.fit_fn(x, y, w, jnp.float32(1e-4), degree)
    big = model.fit_fn(x, y, w, jnp.float32(10.0), degree)
    # exclude intercept (unpenalized) from the norm comparison
    assert float(jnp.linalg.norm(big[1:])) < float(jnp.linalg.norm(small[1:]))


def test_loss_matches_ref():
    rng = np.random.default_rng(4)
    n, d, degree = 64, 7, 2
    p = poly.num_features(d, degree)
    x = jnp.asarray(rng.uniform(-1, 1, (n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    coef = jnp.asarray(rng.standard_normal((p, 3)).astype(np.float32))
    got = model.loss_fn(x, y, w, coef, degree)
    want = ref.mse_ref(x, y, w, coef, degree)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_kfold_cv_selects_planted_degree():
    """A degree-2 ground truth must score best at degree 2 under masked CV —
    the exact protocol the rust coordinator runs against the artifacts."""
    rng = np.random.default_rng(5)
    n, d = 240, 4
    x = jnp.asarray(rng.uniform(-1, 1, (n, d)).astype(np.float32))
    p2 = poly.num_features(d, 2)
    coef_true = jnp.asarray(rng.standard_normal((p2, 3)).astype(np.float32))
    y = ref.predict_ref(x, coef_true, 2)
    y = y + 0.01 * jnp.asarray(rng.standard_normal(y.shape).astype(np.float32))

    k = 4
    fold = np.arange(n) % k
    cv = {}
    for degree in (1, 2, 3):
        errs = []
        for f in range(k):
            w_tr = jnp.asarray((fold != f).astype(np.float32))
            w_te = jnp.asarray((fold == f).astype(np.float32))
            coef = model.fit_fn(x, y, w_tr, jnp.float32(1e-3), degree)
            errs.append(float(jnp.mean(model.loss_fn(x, y, w_te, coef, degree))))
        cv[degree] = np.mean(errs)
    assert cv[2] < cv[1], cv
    # degree 3 nests degree 2, so it may tie; it must not *beat* 2 by much
    assert cv[2] < cv[3] * 1.5, cv


def test_gram_solve_composition_equals_fit():
    """fit_fn must be exactly solve_fn(*gram_fn(...)) — the CV fast path's
    correctness precondition (Gram additivity over folds)."""
    rng = np.random.default_rng(6)
    n, d, degree = 160, 7, 2
    x = jnp.asarray(rng.uniform(-1, 1, (n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    lam = jnp.float32(0.02)
    g, c, n_eff = model.gram_fn(x, y, w, degree)
    via_parts = model.solve_fn(g, c, n_eff, lam)
    direct = model.fit_fn(x, y, w, lam, degree)
    np.testing.assert_allclose(via_parts, direct, rtol=1e-6, atol=1e-6)


def test_gram_additivity_over_folds():
    """G/C/n_eff computed per fold must sum to the full-data Gram."""
    rng = np.random.default_rng(7)
    n, d, degree, k = 120, 5, 2, 3
    x = jnp.asarray(rng.uniform(-1, 1, (n, d)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    fold = np.arange(n) % k
    g_sum = c_sum = n_sum = 0.0
    for f in range(k):
        wf = jnp.asarray((fold == f).astype(np.float32))
        g, c, ne = model.gram_fn(x, y, wf, degree)
        g_sum = g_sum + g
        c_sum = c_sum + c
        n_sum = n_sum + ne
    g_all, c_all, n_all = model.gram_fn(x, y, jnp.ones(n, jnp.float32), degree)
    np.testing.assert_allclose(g_sum, g_all, rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(c_sum, c_all, rtol=5e-5, atol=5e-5)
    np.testing.assert_allclose(n_sum, n_all, rtol=1e-6)
