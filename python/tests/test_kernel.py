"""Kernel-vs-reference correctness: the CORE L1 signal.

Hypothesis sweeps shapes, degrees and value ranges; every Pallas kernel must
agree with the naive pure-jnp oracle in ref.py to tight f32 tolerance.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import poly, ref

jax.config.update("jax_enable_x64", False)

COMMON = dict(deadline=None, max_examples=25,
              suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _x(rng, b, d, scale=2.0):
    return jnp.asarray(rng.uniform(-scale, scale, (b, d)).astype(np.float32))


# ---------------------------------------------------------------------------
# monomial index sets
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,degree,p", [
    (7, 1, 8), (7, 2, 36), (7, 3, 120),  # the shipped D=7 contract
    (1, 3, 4), (2, 2, 6), (3, 1, 4),
])
def test_num_features(d, degree, p):
    assert poly.num_features(d, degree) == p
    assert len(poly.monomial_indices(d, degree)) == p - 1


@given(d=st.integers(1, 8), degree=st.integers(1, 3))
@settings(**COMMON)
def test_monomial_indices_match_ref(d, degree):
    assert poly.monomial_indices(d, degree) == ref.monomial_indices_ref(d, degree)


def test_monomial_indices_sorted_within_tuple():
    for t in poly.monomial_indices(7, 3):
        assert list(t) == sorted(t)


def test_monomial_indices_rejects_bad_args():
    with pytest.raises(ValueError):
        poly.monomial_indices(0, 2)
    with pytest.raises(ValueError):
        poly.monomial_indices(3, 0)


# ---------------------------------------------------------------------------
# polyfeat kernel
# ---------------------------------------------------------------------------


@given(b=st.sampled_from([1, 2, 3, 8, 17, 64]),
       d=st.integers(1, 8), degree=st.integers(1, 3),
       seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_polyfeat_matches_ref(b, d, degree, seed):
    x = _x(np.random.default_rng(seed), b, d)
    got = poly.polyfeat(x, degree, block=b)
    want = ref.polyfeat_ref(x, degree)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block", [32, 64, 128])
def test_polyfeat_blocked_grid(block):
    """Multi-block grids must tile the row dimension transparently."""
    rng = np.random.default_rng(0)
    x = _x(rng, 256, 7)
    got = poly.polyfeat(x, 2, block=block)
    want = ref.polyfeat_ref(x, 2)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_polyfeat_rejects_misaligned_block():
    x = jnp.zeros((100, 7), jnp.float32)
    with pytest.raises(ValueError):
        poly.polyfeat(x, 2, block=64)


def test_polyfeat_constant_column_is_one():
    x = _x(np.random.default_rng(1), 64, 7)
    f = poly.polyfeat(x, 3, block=64)
    np.testing.assert_allclose(f[:, 0], np.ones(64), atol=0)


# ---------------------------------------------------------------------------
# predict kernel (fused expansion + matmul)
# ---------------------------------------------------------------------------


@given(b=st.sampled_from([1, 4, 32, 128]), d=st.integers(1, 8),
       degree=st.integers(1, 3), m=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_predict_matches_ref(b, d, degree, m, seed):
    rng = np.random.default_rng(seed)
    x = _x(rng, b, d)
    p = poly.num_features(d, degree)
    w = jnp.asarray(rng.standard_normal((p, m)).astype(np.float32))
    got = poly.predict(x, w, degree, block=b)
    want = ref.predict_ref(x, w, degree)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_predict_shape_mismatch_raises():
    x = jnp.zeros((8, 7), jnp.float32)
    w = jnp.zeros((10, 3), jnp.float32)  # P should be 36 for degree 2
    with pytest.raises(ValueError):
        poly.predict(x, w, 2, block=8)


def test_predict_multiblock_equals_singleblock():
    rng = np.random.default_rng(7)
    x = _x(rng, 512, 7)
    w = jnp.asarray(rng.standard_normal((36, 3)).astype(np.float32))
    a = poly.predict(x, w, 2, block=512)
    b = poly.predict(x, w, 2, block=64)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# gram kernel (blocked weighted accumulation)
# ---------------------------------------------------------------------------


@given(n=st.sampled_from([1, 2, 16, 96]), d=st.integers(1, 7),
       degree=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
@settings(**COMMON)
def test_gram_matches_ref(n, d, degree, seed):
    rng = np.random.default_rng(seed)
    x = _x(rng, n, d, scale=1.5)
    y = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    g, c = poly.gram(x, y, w, degree, block=n)
    g_ref, c_ref = ref.gram_ref(x, y, w, degree)
    np.testing.assert_allclose(g, g_ref, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(c, c_ref, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("block", [32, 128])
def test_gram_blocked_accumulation(block):
    """Accumulating across grid steps == one-shot reference."""
    rng = np.random.default_rng(3)
    n = 256
    x = _x(rng, n, 7, scale=1.0)
    y = jnp.asarray(rng.standard_normal((n, 3)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 1, n).astype(np.float32))
    g, c = poly.gram(x, y, w, 2, block=block)
    g_ref, c_ref = ref.gram_ref(x, y, w, 2)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(c, c_ref, rtol=1e-4, atol=1e-4)


def test_gram_zero_weights_rows_ignored():
    rng = np.random.default_rng(4)
    x = _x(rng, 128, 7)
    y = jnp.asarray(rng.standard_normal((128, 3)).astype(np.float32))
    w = jnp.concatenate([jnp.ones(64), jnp.zeros(64)]).astype(jnp.float32)
    g_full, c_full = poly.gram(x, y, w, 2, block=64)
    g_half, c_half = poly.gram(x[:64], y[:64], jnp.ones(64, jnp.float32), 2,
                               block=64)
    np.testing.assert_allclose(g_full, g_half, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c_full, c_half, rtol=1e-5, atol=1e-5)


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(5)
    x = _x(rng, 128, 7)
    y = jnp.zeros((128, 3), jnp.float32)
    w = jnp.ones(128, jnp.float32)
    g, _ = poly.gram(x, y, w, 2, block=128)
    g = np.asarray(g)
    np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-4)
    eig = np.linalg.eigvalsh(g.astype(np.float64))
    assert eig.min() > -1e-2 * max(1.0, eig.max())
